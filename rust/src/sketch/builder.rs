//! Sketch construction: the offline (alias-table) path used by the
//! evaluation harness, and the shared plan type. The streaming path lives
//! in [`crate::coordinator`].

use crate::distributions::{Distribution, DistributionKind, MatrixStats};
use crate::error::{Error, Result};
use crate::samplers::AliasTable;
use crate::sparse::Csr;
use crate::util::rng::Rng;

use super::{Sketch, SketchEntry};

/// How to sketch a matrix.
#[derive(Clone, Debug)]
pub struct SketchPlan {
    /// Sampling distribution.
    pub kind: DistributionKind,
    /// Sample budget `s` (i.i.d. draws with replacement).
    pub s: u64,
    /// Failure probability δ (enters Bernstein's α, β).
    pub delta: f64,
    /// RNG seed — all sketches are reproducible.
    pub seed: u64,
}

impl SketchPlan {
    /// Plan with δ = 0.1 and seed 0.
    pub fn new(kind: DistributionKind, s: u64) -> SketchPlan {
        SketchPlan { kind, s, delta: 0.1, seed: 0 }
    }

    /// Override the seed.
    pub fn with_seed(mut self, seed: u64) -> SketchPlan {
        self.seed = seed;
        self
    }

    /// Override δ.
    pub fn with_delta(mut self, delta: f64) -> SketchPlan {
        self.delta = delta;
        self
    }
}

/// Build a sketch of an in-memory CSR matrix by drawing `s` i.i.d. entries
/// from the plan's distribution via one alias table (O(nnz) setup, O(1)
/// per draw).
pub fn sketch_offline(a: &Csr, plan: &SketchPlan) -> Result<Sketch> {
    if plan.s == 0 {
        return Err(Error::invalid("sample budget must be positive"));
    }
    let stats = MatrixStats::from_csr(a);
    let dist = Distribution::prepare(plan.kind, &stats, plan.s, plan.delta)?;

    // flat entry list + weights
    let nnz = a.nnz();
    let mut rows: Vec<u32> = Vec::with_capacity(nnz);
    for i in 0..a.m {
        let c = a.indptr[i + 1] - a.indptr[i];
        rows.extend(std::iter::repeat(i as u32).take(c));
    }
    let mut weights: Vec<f64> = Vec::with_capacity(nnz);
    let mut total_weight = 0.0f64;
    for idx in 0..nnz {
        let w = dist.weight(rows[idx], a.values[idx]);
        total_weight += w;
        weights.push(w);
    }
    if total_weight <= 0.0 {
        return Err(Error::invalid(format!(
            "{} assigns zero weight to every entry",
            plan.kind.name()
        )));
    }

    let table = AliasTable::new(&weights);
    let mut rng = Rng::new(plan.seed);
    let mut counts: std::collections::HashMap<usize, u32> = Default::default();
    for _ in 0..plan.s {
        *counts.entry(table.sample(&mut rng)).or_default() += 1;
    }

    let mut entries: Vec<SketchEntry> = counts
        .into_iter()
        .map(|(idx, count)| {
            let p = weights[idx] / total_weight;
            SketchEntry {
                row: rows[idx],
                col: a.indices[idx],
                count,
                value: count as f64 * a.values[idx] as f64 / (plan.s as f64 * p),
            }
        })
        .collect();
    entries.sort_unstable_by(|x, y| (x.row, x.col).cmp(&(y.row, y.col)));

    // per-row codec scale for the L1 family
    let row_scale = dist.rho.as_ref().map(|rho| {
        rho.iter()
            .zip(stats.row_l1.iter())
            .map(|(&r, &z)| if r > 0.0 { z / (plan.s as f64 * r) } else { 0.0 })
            .collect()
    });

    Ok(Sketch {
        m: a.m,
        n: a.n,
        s: plan.s,
        entries,
        row_scale,
        method: plan.kind.name(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Entry};

    fn toy_csr() -> Csr {
        let mut coo = Coo::new(4, 8);
        let mut rng = Rng::new(99);
        for i in 0..4u32 {
            for j in 0..8u32 {
                coo.push(i, j, (rng.normal() as f32) * (1.0 + i as f32));
            }
        }
        coo.to_csr()
    }

    #[test]
    fn total_count_is_s() {
        let a = toy_csr();
        for kind in DistributionKind::figure1_set() {
            let sk = sketch_offline(&a, &SketchPlan::new(kind, 500).with_seed(1)).unwrap();
            let total: u64 = sk.entries.iter().map(|e| e.count as u64).sum();
            assert_eq!(total, 500, "{}", sk.method);
            assert_eq!(sk.s, 500);
        }
    }

    #[test]
    fn sketch_is_unbiased_estimator() {
        // E[B_ij] = A_ij: average many sketches and compare entrywise.
        let a = Coo::from_entries(
            2,
            2,
            vec![
                Entry::new(0, 0, 5.0),
                Entry::new(0, 1, -2.0),
                Entry::new(1, 0, 1.0),
                Entry::new(1, 1, 4.0),
            ],
        )
        .unwrap()
        .to_csr();
        let trials = 3000u64;
        let mut acc = vec![0.0f64; 4];
        for t in 0..trials {
            let sk = sketch_offline(
                &a,
                &SketchPlan::new(DistributionKind::Bernstein, 8).with_seed(t),
            )
            .unwrap();
            for e in &sk.entries {
                acc[(e.row * 2 + e.col) as usize] += e.value;
            }
        }
        let want = [5.0, -2.0, 1.0, 4.0];
        for i in 0..4 {
            let mean = acc[i] / trials as f64;
            assert!(
                (mean - want[i]).abs() < 0.25,
                "entry {i}: mean={mean} want={}",
                want[i]
            );
        }
    }

    #[test]
    fn bernstein_values_are_row_constants() {
        // For the L1 family, |B_ij|/count must equal the row scale.
        let a = toy_csr();
        let sk = sketch_offline(
            &a,
            &SketchPlan::new(DistributionKind::Bernstein, 2_000).with_seed(5),
        )
        .unwrap();
        let scale = sk.row_scale.as_ref().unwrap();
        for e in &sk.entries {
            let per_draw = e.value.abs() / e.count as f64;
            let want = scale[e.row as usize];
            assert!(
                (per_draw - want).abs() / want < 1e-9,
                "row {}: {per_draw} vs {want}",
                e.row
            );
        }
    }

    #[test]
    fn entries_sorted_row_major() {
        let a = toy_csr();
        let sk = sketch_offline(&a, &SketchPlan::new(DistributionKind::L1, 300)).unwrap();
        assert!(sk
            .entries
            .windows(2)
            .all(|w| (w[0].row, w[0].col) < (w[1].row, w[1].col)));
    }

    #[test]
    fn rejects_zero_budget() {
        let a = toy_csr();
        assert!(sketch_offline(&a, &SketchPlan::new(DistributionKind::L1, 0)).is_err());
    }
}
