//! Bit-level I/O and Elias-γ codes for the sketch codec.
//!
//! Both ends work on a **64-bit staging word** instead of one bit per
//! call: the writer accumulates fields in a word and flushes whole bytes,
//! the reader refills a word from the buffer and peels a whole γ code off
//! it with `leading_zeros` plus one shift. The bit layout is exactly the
//! historical MSB-first one — every `.msk` file and wire frame written by
//! the scalar codec round-trips unchanged (pinned against the [`scalar`]
//! reference implementations by property tests below), and the
//! bit-granular API (`put_bit` / `get_bit`) remains available.

/// MSB-first bit writer (word-level staging).
#[derive(Default, Debug)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Pending bits: the low `nbits` bits of `acc`, MSB-first. Bits above
    /// `nbits` are garbage (shifted-up remnants) and must be masked off
    /// before use; the flush loop below only ever reads below `nbits`.
    acc: u64,
    /// Valid bit count in `acc`; `< 8` after every public call.
    nbits: u32,
}

impl BitWriter {
    /// Empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one bit.
    #[inline]
    pub fn put_bit(&mut self, bit: bool) {
        self.acc = (self.acc << 1) | bit as u64;
        self.nbits += 1;
        if self.nbits == 8 {
            self.buf.push(self.acc as u8);
            self.nbits = 0;
        }
    }

    /// Append the low `n ≤ 64` bits of `v`, MSB first — one shift-or into
    /// the staging word plus whole-byte flushes, never a per-bit loop.
    #[inline]
    pub fn put_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64, "put_bits width {n} > 64");
        if n == 0 {
            return;
        }
        if n > 57 {
            // staging headroom is 64 - 7 = 57 bits; split wide fields
            self.put_bits(v >> 32, n - 32);
            self.put_bits(v & 0xFFFF_FFFF, 32);
            return;
        }
        self.acc = (self.acc << n) | (v & ((1u64 << n) - 1));
        self.nbits += n;
        while self.nbits >= 8 {
            self.nbits -= 8;
            self.buf.push((self.acc >> self.nbits) as u8);
        }
    }

    /// Elias-γ code of `v ≥ 1`: (⌊log₂v⌋ zeros) then v's binary digits.
    /// The zeros are implicit high bits of the value, so codes up to 64
    /// bits long are a single `put_bits` call.
    #[inline]
    pub fn put_gamma(&mut self, v: u64) {
        debug_assert!(v >= 1);
        let nbits = 64 - v.leading_zeros();
        let len = 2 * nbits - 1;
        if len <= 64 {
            self.put_bits(v, len);
        } else {
            self.put_bits(0, len - 64);
            self.put_bits(v, 64);
        }
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Finish (pad the final byte with zeros) and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            let tail = (self.acc & ((1u64 << self.nbits) - 1)) as u8;
            self.buf.push(tail << (8 - self.nbits));
        }
        self.buf
    }
}

/// MSB-first bit reader (word-level staging).
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Absolute bit position of the next unread bit.
    pos: usize,
    /// The next `avail` unread bits, MSB-aligned. Bits below the valid
    /// region are either zero or correct lookahead for the bytes at
    /// `next_byte` onward (see `refill`) — consumers only ever read the
    /// top `avail` bits.
    word: u64,
    avail: u32,
    /// First byte of `buf` not yet loaded into `word`.
    next_byte: usize,
}

impl<'a> BitReader<'a> {
    /// Read from a byte buffer.
    pub fn new(buf: &'a [u8]) -> Self {
        Self::new_at(buf, 0)
    }

    /// Read from a byte buffer starting at bit position `bit_pos` — the
    /// seek primitive behind cached-header cursor opens and the per-row
    /// offset index. A position past the end is legal and yields `None`
    /// on the first read, exactly like an exhausted reader.
    pub fn new_at(buf: &'a [u8], bit_pos: usize) -> Self {
        let mut r = BitReader { buf, pos: bit_pos, word: 0, avail: 0, next_byte: bit_pos / 8 };
        let skip = (bit_pos % 8) as u32;
        if skip != 0 {
            // prime the unaligned first byte, dropping its consumed bits
            if let Some(&b) = buf.get(r.next_byte) {
                r.word = (b as u64) << (56 + skip);
                r.avail = 8 - skip;
            }
            r.next_byte += 1;
        }
        r
    }

    /// Top up the staging word from the buffer (to ≥ 56 bits unless the
    /// buffer runs out first). Mid-buffer this is **one** 8-byte load
    /// OR-merged below the valid bits, advancing past the whole bytes it
    /// accounts for (`avail |= 56` claims 56–63 bits): the sub-byte
    /// remainder bits it leaves in the word are correct lookahead from
    /// the not-yet-advanced byte, so the next refill (either path) ORs
    /// the same values over them — idempotent by construction.
    #[inline]
    fn refill(&mut self) {
        let window = self
            .buf
            .get(self.next_byte..self.next_byte.saturating_add(8))
            .and_then(|w| <[u8; 8]>::try_from(w).ok());
        if let Some(bytes) = window {
            self.word |= u64::from_be_bytes(bytes) >> self.avail;
            self.next_byte += ((63 - self.avail) >> 3) as usize;
            self.avail |= 56;
            return;
        }
        while self.avail <= 56 {
            let Some(&b) = self.buf.get(self.next_byte) else { break };
            self.word |= (b as u64) << (56 - self.avail);
            self.avail += 8;
            self.next_byte += 1;
        }
    }

    /// Next bit; `None` past the end.
    #[inline]
    pub fn get_bit(&mut self) -> Option<bool> {
        if self.avail == 0 {
            self.refill();
            if self.avail == 0 {
                return None;
            }
        }
        let bit = self.word >> 63 == 1;
        self.word <<= 1;
        self.avail -= 1;
        self.pos += 1;
        Some(bit)
    }

    /// Next `n ≤ 64` bits as an integer — one shift off the staging word.
    /// `None` (without consuming) when fewer than `n` bits remain.
    #[inline]
    pub fn get_bits(&mut self, n: u32) -> Option<u64> {
        debug_assert!(n <= 64, "get_bits width {n} > 64");
        if n == 0 {
            return Some(0);
        }
        if n > 56 {
            // wide fields split into two staging-word reads (a refill
            // only guarantees ≥ 56 bits); check the whole width up front
            // so a failed read consumes nothing
            if (self.buf.len() * 8).saturating_sub(self.pos) < n as usize {
                return None;
            }
            let hi = self.get_bits(n - 32)?;
            let lo = self.get_bits(32)?;
            return Some((hi << 32) | lo);
        }
        if self.avail < n {
            self.refill();
            if self.avail < n {
                return None;
            }
        }
        let v = self.word >> (64 - n);
        self.word <<= n;
        self.avail -= n;
        self.pos += n as usize;
        Some(v)
    }

    /// Decode one Elias-γ value: count the zero run with `leading_zeros`
    /// on the staging word and peel the whole code in one shift when it
    /// fits (always, for codes ≤ 56 bits after a refill); codes straddling
    /// the word fall back to the bit-granular scan.
    #[inline]
    pub fn get_gamma(&mut self) -> Option<u64> {
        if self.avail < 56 {
            self.refill();
        }
        let lz = self.word.leading_zeros();
        if lz < self.avail {
            let total = 2 * lz + 1; // odd, and ≤ avail ≤ 64 on this path
            if total <= self.avail {
                let v = self.word >> (64 - total);
                self.word <<= total;
                self.avail -= total;
                self.pos += total as usize;
                return Some(v);
            }
        }
        // slow path: the code straddles the staging word (> 56 bits of
        // zeros + digits) or the stream ends inside it
        let mut zeros = 0u32;
        while !self.get_bit()? {
            zeros += 1;
            if zeros >= 64 {
                return None;
            }
        }
        if zeros == 0 {
            return Some(1);
        }
        let rest = self.get_bits(zeros)?;
        Some((1u64 << zeros) | rest)
    }

    /// Current bit position.
    pub fn bit_pos(&self) -> usize {
        self.pos
    }
}

/// The original one-bit-per-call codec, kept as (a) the reference the
/// word-level [`BitWriter`]/[`BitReader`] are pinned against by the
/// property tests in this module, and (b) the baseline `bench_bitio`
/// measures the word-level speedup over. Verbatim except one deliberate
/// alignment: the malformed-γ zero-run guard is `>= 64` (matching the
/// word reader) instead of the old `> 64`, which could shift-overflow
/// on a 64-zero run. Not used on any serving or encode path.
pub mod scalar {
    /// MSB-first bit writer, one bit per call (reference implementation).
    #[derive(Default, Debug)]
    pub struct ScalarBitWriter {
        buf: Vec<u8>,
        cur: u8,
        nbits: u8,
    }

    impl ScalarBitWriter {
        /// Empty writer.
        pub fn new() -> Self {
            Self::default()
        }

        /// Append one bit.
        #[inline]
        pub fn put_bit(&mut self, bit: bool) {
            self.cur = (self.cur << 1) | bit as u8;
            self.nbits += 1;
            if self.nbits == 8 {
                self.buf.push(self.cur);
                self.cur = 0;
                self.nbits = 0;
            }
        }

        /// Append the low `n` bits of `v`, MSB first (bit-at-a-time).
        pub fn put_bits(&mut self, v: u64, n: u32) {
            for i in (0..n).rev() {
                self.put_bit((v >> i) & 1 == 1);
            }
        }

        /// Elias-γ code of `v ≥ 1` (bit-at-a-time).
        pub fn put_gamma(&mut self, v: u64) {
            debug_assert!(v >= 1);
            let nbits = 64 - v.leading_zeros();
            for _ in 0..nbits - 1 {
                self.put_bit(false);
            }
            self.put_bits(v, nbits);
        }

        /// Total bits written so far.
        pub fn bit_len(&self) -> usize {
            self.buf.len() * 8 + self.nbits as usize
        }

        /// Finish (pad the final byte with zeros) and return the buffer.
        pub fn finish(mut self) -> Vec<u8> {
            if self.nbits > 0 {
                self.cur <<= 8 - self.nbits;
                self.buf.push(self.cur);
            }
            self.buf
        }
    }

    /// MSB-first bit reader, one bit per call (reference implementation).
    #[derive(Debug)]
    pub struct ScalarBitReader<'a> {
        buf: &'a [u8],
        pos: usize, // bit position
    }

    impl<'a> ScalarBitReader<'a> {
        /// Read from a byte buffer.
        pub fn new(buf: &'a [u8]) -> Self {
            Self { buf, pos: 0 }
        }

        /// Read from bit position `bit_pos`.
        pub fn new_at(buf: &'a [u8], bit_pos: usize) -> Self {
            Self { buf, pos: bit_pos }
        }

        /// Next bit; `None` past the end.
        #[inline]
        pub fn get_bit(&mut self) -> Option<bool> {
            let byte = self.buf.get(self.pos / 8)?;
            let bit = (byte >> (7 - self.pos % 8)) & 1 == 1;
            self.pos += 1;
            Some(bit)
        }

        /// Next `n` bits as an integer (bit-at-a-time).
        pub fn get_bits(&mut self, n: u32) -> Option<u64> {
            let mut v = 0u64;
            for _ in 0..n {
                v = (v << 1) | self.get_bit()? as u64;
            }
            Some(v)
        }

        /// Decode one Elias-γ value (bit-at-a-time).
        pub fn get_gamma(&mut self) -> Option<u64> {
            let mut zeros = 0u32;
            while !self.get_bit()? {
                zeros += 1;
                if zeros >= 64 {
                    return None;
                }
            }
            let rest = self.get_bits(zeros)?;
            Some((1u64 << zeros) | rest)
        }

        /// Current bit position.
        pub fn bit_pos(&self) -> usize {
            self.pos
        }
    }
}

#[cfg(test)]
mod tests {
    use super::scalar::{ScalarBitReader, ScalarBitWriter};
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bits_roundtrip() {
        let mut w = BitWriter::new();
        w.put_bits(0b101101, 6);
        w.put_bits(0xDEAD, 16);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.get_bits(6), Some(0b101101));
        assert_eq!(r.get_bits(16), Some(0xDEAD));
    }

    #[test]
    fn wide_fields_roundtrip_at_every_alignment() {
        // 57..64-bit fields take a split path (writer splits above 57,
        // reader above 56); run them at every staging alignment.
        for lead in 0..8u32 {
            let mut w = BitWriter::new();
            w.put_bits(0x5A, lead);
            for n in 57..=64u32 {
                let v = 0xDEAD_BEEF_CAFE_F00Du64 & (!0u64 >> (64 - n));
                w.put_bits(v, n);
            }
            w.put_bits(u64::MAX, 64);
            let buf = w.finish();
            let mut r = BitReader::new(&buf);
            assert_eq!(r.get_bits(lead), Some(0x5Au64 & ((1 << lead) - 1)));
            for n in 57..=64u32 {
                let v = 0xDEAD_BEEF_CAFE_F00Du64 & (!0u64 >> (64 - n));
                assert_eq!(r.get_bits(n), Some(v), "lead={lead} n={n}");
            }
            assert_eq!(r.get_bits(64), Some(u64::MAX));
        }
    }

    #[test]
    fn gamma_roundtrip_exhaustive_small() {
        let mut w = BitWriter::new();
        for v in 1..=300u64 {
            w.put_gamma(v);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for v in 1..=300u64 {
            assert_eq!(r.get_gamma(), Some(v));
        }
    }

    #[test]
    fn gamma_roundtrip_random_large() {
        let mut rng = Rng::new(0);
        let vals: Vec<u64> = (0..2000).map(|_| rng.u64_below(1 << 40) + 1).collect();
        let mut w = BitWriter::new();
        for &v in &vals {
            w.put_gamma(v);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for &v in &vals {
            assert_eq!(r.get_gamma(), Some(v));
        }
    }

    #[test]
    fn gamma_length_is_2floorlog_plus_1() {
        for (v, len) in [(1u64, 1usize), (2, 3), (3, 3), (4, 5), (255, 15), (256, 17)] {
            let mut w = BitWriter::new();
            w.put_gamma(v);
            assert_eq!(w.bit_len(), len, "v={v}");
        }
    }

    #[test]
    fn new_at_resumes_mid_stream() {
        let mut w = BitWriter::new();
        w.put_bits(0b1011, 4);
        w.put_gamma(77);
        w.put_gamma(5);
        let buf = w.finish();
        // position of γ(5): 4 prefix bits + |γ(77)| = 13 bits
        let mut r = BitReader::new_at(&buf, 17);
        assert_eq!(r.get_gamma(), Some(5));
        // past-the-end start is a clean immediate end
        let mut r = BitReader::new_at(&buf, buf.len() * 8);
        assert_eq!(r.get_bit(), None);
        // far-past-the-end, at every bit alignment, is too
        for off in 0..16 {
            let mut r = BitReader::new_at(&buf, buf.len() * 8 + 1 + off);
            assert_eq!(r.get_bit(), None, "offset {off}");
        }
    }

    #[test]
    fn reader_stops_at_end() {
        let buf = [0xFFu8];
        let mut r = BitReader::new(&buf);
        assert_eq!(r.get_bits(8), Some(0xFF));
        assert_eq!(r.get_bit(), None);
    }

    #[test]
    fn failed_wide_read_consumes_nothing() {
        // a 58..64-bit read that cannot be satisfied must leave the
        // cursor exactly where it was (the split into two staging-word
        // pulls is checked against the whole width up front)
        let mut w = BitWriter::new();
        w.put_bits(0xABCD, 16);
        w.put_gamma(9);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.get_bits(64), None);
        assert_eq!(r.bit_pos(), 0, "failed wide read moved the cursor");
        assert_eq!(r.get_bits(16), Some(0xABCD));
        assert_eq!(r.get_gamma(), Some(9));
    }

    #[test]
    fn malformed_64_zero_run_rejected_by_both_readers() {
        // 64 zero bits then a 1: no valid γ code starts with ≥ 64 zeros,
        // and both readers must agree (the scalar reference's guard is
        // deliberately aligned to the word reader's)
        let mut buf = vec![0u8; 8];
        buf.push(0x80);
        assert_eq!(BitReader::new(&buf).get_gamma(), None);
        assert_eq!(ScalarBitReader::new(&buf).get_gamma(), None);
    }

    #[test]
    fn gamma_v1_and_u64_max_edges_roundtrip() {
        // v=1 is the shortest code (a single 1-bit); v=u64::MAX the
        // longest (63 zeros + 64 digits = 127 bits). Adjacent values make
        // sure neither code bleeds into its neighbours.
        let mut w = BitWriter::new();
        w.put_gamma(1);
        w.put_gamma(u64::MAX);
        w.put_gamma(1);
        w.put_gamma(u64::MAX - 1);
        assert_eq!(w.bit_len(), 1 + 127 + 1 + 127);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.get_gamma(), Some(1));
        assert_eq!(r.get_gamma(), Some(u64::MAX));
        assert_eq!(r.get_gamma(), Some(1));
        assert_eq!(r.get_gamma(), Some(u64::MAX - 1));
    }

    #[test]
    fn final_byte_padding_boundary() {
        // Every alignment of the final byte: n written bits leave
        // (8 - n % 8) % 8 zero pad bits, which must neither corrupt the
        // payload nor decode as an extra value.
        for n in 1..=32u32 {
            let mut w = BitWriter::new();
            for i in 0..n {
                w.put_bit(i % 2 == 0);
            }
            assert_eq!(w.bit_len(), n as usize);
            let buf = w.finish();
            assert_eq!(buf.len(), (n as usize).div_ceil(8), "n={n}");
            let mut r = BitReader::new(&buf);
            for i in 0..n {
                assert_eq!(r.get_bit(), Some(i % 2 == 0), "n={n} bit {i}");
            }
            // pad bits are zeros, then a hard end
            for _ in n..(buf.len() as u32 * 8) {
                assert_eq!(r.get_bit(), Some(false), "n={n}: pad bit not zero");
            }
            assert_eq!(r.get_bit(), None, "n={n}: read past the buffer");
        }
    }

    #[test]
    fn padding_never_decodes_as_a_value() {
        // 5-bit payload (γ(5) = 00101) leaves 3 zero pad bits: a decoder
        // walking the stream must get exactly one value then a clean end.
        let mut w = BitWriter::new();
        w.put_gamma(5);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.get_gamma(), Some(5));
        assert_eq!(r.get_gamma(), None);
    }

    #[test]
    fn prop_gamma_stream_roundtrips_bit_exact() {
        use crate::testing::prop::{check, shrink_vec, PropConfig};
        check(
            PropConfig { cases: 150, seed: 0xB170 },
            |rng| {
                let n = 1 + rng.usize_below(80);
                (0..n)
                    .map(|_| match rng.u64_below(5) {
                        0 => 1 + rng.u64_below(8),                 // shortest codes
                        1 => 1 + rng.u64_below(1 << 16),           // mid-range
                        2 => u64::MAX - rng.u64_below(1 << 8),     // near the top
                        3 => (1u64 << (rng.u64_below(63) as u32)), // power-of-two boundaries
                        _ => (rng.next_u64() >> (rng.u64_below(64) as u32)).max(1),
                    })
                    .collect::<Vec<u64>>()
            },
            |v| shrink_vec(v),
            |vals| {
                let mut w = BitWriter::new();
                for &v in vals {
                    w.put_gamma(v);
                }
                let payload_bits = w.bit_len();
                let buf = w.finish();
                // finish() pads the final byte with < 8 zero bits
                let padded = buf.len() * 8;
                if padded < payload_bits || padded - payload_bits >= 8 {
                    return false;
                }
                let mut r = BitReader::new(&buf);
                vals.iter().all(|&v| r.get_gamma() == Some(v))
                    && r.bit_pos() == payload_bits
            },
        );
    }

    #[test]
    fn prop_mixed_bits_and_gammas_roundtrip() {
        // The codec interleaves fixed-width fields, γ codes, and sign
        // bits; the bit cursor must stay exact across any interleaving.
        use crate::testing::prop::{check, shrink_vec, PropConfig};
        check(
            PropConfig { cases: 100, seed: 0xB171 },
            |rng| {
                let n = 1 + rng.usize_below(40);
                (0..n)
                    .map(|_| {
                        let width = 1 + rng.u64_below(32) as u32;
                        let value = rng.next_u64() & ((1u64 << width) - 1);
                        let gamma = 1 + rng.u64_below(1 << 20);
                        let sign = rng.bernoulli(0.5);
                        (width, value, gamma, sign)
                    })
                    .collect::<Vec<(u32, u64, u64, bool)>>()
            },
            |v| shrink_vec(v),
            |fields| {
                let mut w = BitWriter::new();
                for &(width, value, gamma, sign) in fields {
                    w.put_bits(value, width);
                    w.put_gamma(gamma);
                    w.put_bit(sign);
                }
                let buf = w.finish();
                let mut r = BitReader::new(&buf);
                fields.iter().all(|&(width, value, gamma, sign)| {
                    r.get_bits(width) == Some(value)
                        && r.get_gamma() == Some(gamma)
                        && r.get_bit() == Some(sign)
                })
            },
        );
    }

    /// One random field of a mixed stream (the shapes the sketch codec
    /// and the store container actually write).
    #[derive(Clone, Copy, Debug)]
    enum Field {
        Bits(u64, u32),
        Gamma(u64),
        Bit(bool),
    }

    fn random_fields(rng: &mut Rng) -> Vec<Field> {
        let n = 1 + rng.usize_below(120);
        (0..n)
            .map(|_| match rng.u64_below(8) {
                0 => Field::Bit(rng.bernoulli(0.5)),
                1 => {
                    // wide fixed fields incl. the 58..64 split path
                    let w = 33 + rng.u64_below(32) as u32;
                    let v = rng.next_u64() & (!0u64 >> (64 - w));
                    Field::Bits(v, w)
                }
                2 => Field::Bits(rng.next_u64() & 0xFFFF_FFFF, 32),
                3 => Field::Gamma(1),
                4 => Field::Gamma(u64::MAX - rng.u64_below(4)),
                5 => Field::Gamma(1u64 << rng.u64_below(64) as u32),
                6 => Field::Gamma(1 + rng.u64_below(1 << 20)),
                _ => {
                    let w = 1 + rng.u64_below(16) as u32;
                    Field::Bits(rng.next_u64() & ((1u64 << w) - 1), w)
                }
            })
            .collect()
    }

    /// Satellite pin: on random mixed γ / raw-bit / sign streams —
    /// including `u64::MAX` γ codes and every final-byte padding
    /// alignment — the word-level writer emits byte-identical buffers to
    /// the scalar reference, and both readers decode each other's output
    /// with identical values and bit positions.
    #[test]
    fn prop_word_level_codec_pins_scalar_reference() {
        use crate::testing::prop::{check, shrink_vec, PropConfig};
        check(
            PropConfig { cases: 200, seed: 0xB172 },
            |rng| random_fields(rng),
            |v| shrink_vec(v),
            |fields| {
                let mut word_w = BitWriter::new();
                let mut scalar_w = ScalarBitWriter::new();
                for &f in fields {
                    match f {
                        Field::Bits(v, n) => {
                            word_w.put_bits(v, n);
                            scalar_w.put_bits(v, n);
                        }
                        Field::Gamma(v) => {
                            word_w.put_gamma(v);
                            scalar_w.put_gamma(v);
                        }
                        Field::Bit(b) => {
                            word_w.put_bit(b);
                            scalar_w.put_bit(b);
                        }
                    }
                    if word_w.bit_len() != scalar_w.bit_len() {
                        return false;
                    }
                }
                let word_buf = word_w.finish();
                let scalar_buf = scalar_w.finish();
                if word_buf != scalar_buf {
                    return false; // byte-identical on disk
                }
                // cross-decode: each reader over the shared buffer
                let mut word_r = BitReader::new(&word_buf);
                let mut scalar_r = ScalarBitReader::new(&word_buf);
                for &f in fields {
                    let ok = match f {
                        Field::Bits(v, n) => {
                            word_r.get_bits(n) == Some(v) && scalar_r.get_bits(n) == Some(v)
                        }
                        Field::Gamma(v) => {
                            word_r.get_gamma() == Some(v)
                                && scalar_r.get_gamma() == Some(v)
                        }
                        Field::Bit(b) => {
                            word_r.get_bit() == Some(b) && scalar_r.get_bit() == Some(b)
                        }
                    };
                    if !ok || word_r.bit_pos() != scalar_r.bit_pos() {
                        return false;
                    }
                }
                // past the payload both hit the same padded-zero tail and
                // the same hard end
                loop {
                    let (a, b) = (word_r.get_bit(), scalar_r.get_bit());
                    if a != b {
                        return false;
                    }
                    if a.is_none() {
                        return true;
                    }
                }
            },
        );
    }

    /// Mid-stream seeks (`new_at`) agree with the scalar reference at
    /// every bit offset of a mixed stream.
    #[test]
    fn word_reader_seeks_match_scalar_at_every_offset() {
        let mut rng = Rng::new(0xB173);
        let fields = random_fields(&mut rng);
        let mut w = BitWriter::new();
        for &f in &fields {
            match f {
                Field::Bits(v, n) => w.put_bits(v, n),
                Field::Gamma(v) => w.put_gamma(v),
                Field::Bit(b) => w.put_bit(b),
            }
        }
        let buf = w.finish();
        for start in 0..buf.len() * 8 {
            let mut word_r = BitReader::new_at(&buf, start);
            let mut scalar_r = ScalarBitReader::new_at(&buf, start);
            for _ in 0..3 {
                let (a, b) = (word_r.get_bits(7), scalar_r.get_bits(7));
                assert_eq!(a, b, "start={start}");
                if a.is_none() {
                    // on a failed read the two impls may leave the
                    // cursor differently (the word reader consumes
                    // nothing); past this point only values matter
                    break;
                }
                assert_eq!(word_r.bit_pos(), scalar_r.bit_pos(), "start={start}");
            }
        }
    }
}
