//! Bit-level I/O and Elias-γ codes for the sketch codec.

/// MSB-first bit writer.
#[derive(Default, Debug)]
pub struct BitWriter {
    buf: Vec<u8>,
    cur: u8,
    nbits: u8,
}

impl BitWriter {
    /// Empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one bit.
    #[inline]
    pub fn put_bit(&mut self, bit: bool) {
        self.cur = (self.cur << 1) | bit as u8;
        self.nbits += 1;
        if self.nbits == 8 {
            self.buf.push(self.cur);
            self.cur = 0;
            self.nbits = 0;
        }
    }

    /// Append the low `n` bits of `v`, MSB first.
    pub fn put_bits(&mut self, v: u64, n: u32) {
        for i in (0..n).rev() {
            self.put_bit((v >> i) & 1 == 1);
        }
    }

    /// Elias-γ code of `v ≥ 1`: (⌊log₂v⌋ zeros) then v's binary digits.
    pub fn put_gamma(&mut self, v: u64) {
        debug_assert!(v >= 1);
        let nbits = 64 - v.leading_zeros();
        for _ in 0..nbits - 1 {
            self.put_bit(false);
        }
        self.put_bits(v, nbits);
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Finish (pad the final byte with zeros) and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.cur <<= 8 - self.nbits;
            self.buf.push(self.cur);
        }
        self.buf
    }
}

/// MSB-first bit reader.
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // bit position
}

impl<'a> BitReader<'a> {
    /// Read from a byte buffer.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Read from a byte buffer starting at bit position `bit_pos` — the
    /// seek primitive behind cached-header cursor opens and the per-row
    /// offset index. A position past the end is legal and yields `None`
    /// on the first read, exactly like an exhausted reader.
    pub fn new_at(buf: &'a [u8], bit_pos: usize) -> Self {
        Self { buf, pos: bit_pos }
    }

    /// Next bit; `None` past the end.
    #[inline]
    pub fn get_bit(&mut self) -> Option<bool> {
        let byte = self.buf.get(self.pos / 8)?;
        let bit = (byte >> (7 - self.pos % 8)) & 1 == 1;
        self.pos += 1;
        Some(bit)
    }

    /// Next `n` bits as an integer.
    pub fn get_bits(&mut self, n: u32) -> Option<u64> {
        let mut v = 0u64;
        for _ in 0..n {
            v = (v << 1) | self.get_bit()? as u64;
        }
        Some(v)
    }

    /// Decode one Elias-γ value.
    pub fn get_gamma(&mut self) -> Option<u64> {
        let mut zeros = 0u32;
        while !self.get_bit()? {
            zeros += 1;
            if zeros > 64 {
                return None;
            }
        }
        let rest = self.get_bits(zeros)?;
        Some((1u64 << zeros) | rest)
    }

    /// Current bit position.
    pub fn bit_pos(&self) -> usize {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bits_roundtrip() {
        let mut w = BitWriter::new();
        w.put_bits(0b101101, 6);
        w.put_bits(0xDEAD, 16);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.get_bits(6), Some(0b101101));
        assert_eq!(r.get_bits(16), Some(0xDEAD));
    }

    #[test]
    fn gamma_roundtrip_exhaustive_small() {
        let mut w = BitWriter::new();
        for v in 1..=300u64 {
            w.put_gamma(v);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for v in 1..=300u64 {
            assert_eq!(r.get_gamma(), Some(v));
        }
    }

    #[test]
    fn gamma_roundtrip_random_large() {
        let mut rng = Rng::new(0);
        let vals: Vec<u64> = (0..2000).map(|_| rng.u64_below(1 << 40) + 1).collect();
        let mut w = BitWriter::new();
        for &v in &vals {
            w.put_gamma(v);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for &v in &vals {
            assert_eq!(r.get_gamma(), Some(v));
        }
    }

    #[test]
    fn gamma_length_is_2floorlog_plus_1() {
        for (v, len) in [(1u64, 1usize), (2, 3), (3, 3), (4, 5), (255, 15), (256, 17)] {
            let mut w = BitWriter::new();
            w.put_gamma(v);
            assert_eq!(w.bit_len(), len, "v={v}");
        }
    }

    #[test]
    fn new_at_resumes_mid_stream() {
        let mut w = BitWriter::new();
        w.put_bits(0b1011, 4);
        w.put_gamma(77);
        w.put_gamma(5);
        let buf = w.finish();
        // position of γ(5): 4 prefix bits + |γ(77)| = 13 bits
        let mut r = BitReader::new_at(&buf, 17);
        assert_eq!(r.get_gamma(), Some(5));
        // past-the-end start is a clean immediate end
        let mut r = BitReader::new_at(&buf, buf.len() * 8);
        assert_eq!(r.get_bit(), None);
    }

    #[test]
    fn reader_stops_at_end() {
        let buf = [0xFFu8];
        let mut r = BitReader::new(&buf);
        assert_eq!(r.get_bits(8), Some(0xFF));
        assert_eq!(r.get_bit(), None);
    }

    #[test]
    fn gamma_v1_and_u64_max_edges_roundtrip() {
        // v=1 is the shortest code (a single 1-bit); v=u64::MAX the
        // longest (63 zeros + 64 digits = 127 bits). Adjacent values make
        // sure neither code bleeds into its neighbours.
        let mut w = BitWriter::new();
        w.put_gamma(1);
        w.put_gamma(u64::MAX);
        w.put_gamma(1);
        w.put_gamma(u64::MAX - 1);
        assert_eq!(w.bit_len(), 1 + 127 + 1 + 127);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.get_gamma(), Some(1));
        assert_eq!(r.get_gamma(), Some(u64::MAX));
        assert_eq!(r.get_gamma(), Some(1));
        assert_eq!(r.get_gamma(), Some(u64::MAX - 1));
    }

    #[test]
    fn final_byte_padding_boundary() {
        // Every alignment of the final byte: n written bits leave
        // (8 - n % 8) % 8 zero pad bits, which must neither corrupt the
        // payload nor decode as an extra value.
        for n in 1..=32u32 {
            let mut w = BitWriter::new();
            for i in 0..n {
                w.put_bit(i % 2 == 0);
            }
            assert_eq!(w.bit_len(), n as usize);
            let buf = w.finish();
            assert_eq!(buf.len(), (n as usize).div_ceil(8), "n={n}");
            let mut r = BitReader::new(&buf);
            for i in 0..n {
                assert_eq!(r.get_bit(), Some(i % 2 == 0), "n={n} bit {i}");
            }
            // pad bits are zeros, then a hard end
            for _ in n..(buf.len() as u32 * 8) {
                assert_eq!(r.get_bit(), Some(false), "n={n}: pad bit not zero");
            }
            assert_eq!(r.get_bit(), None, "n={n}: read past the buffer");
        }
    }

    #[test]
    fn padding_never_decodes_as_a_value() {
        // 5-bit payload (γ(5) = 00101) leaves 3 zero pad bits: a decoder
        // walking the stream must get exactly one value then a clean end.
        let mut w = BitWriter::new();
        w.put_gamma(5);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.get_gamma(), Some(5));
        assert_eq!(r.get_gamma(), None);
    }

    #[test]
    fn prop_gamma_stream_roundtrips_bit_exact() {
        use crate::testing::prop::{check, shrink_vec, PropConfig};
        check(
            PropConfig { cases: 150, seed: 0xB170 },
            |rng| {
                let n = 1 + rng.usize_below(80);
                (0..n)
                    .map(|_| match rng.u64_below(5) {
                        0 => 1 + rng.u64_below(8),                 // shortest codes
                        1 => 1 + rng.u64_below(1 << 16),           // mid-range
                        2 => u64::MAX - rng.u64_below(1 << 8),     // near the top
                        3 => (1u64 << (rng.u64_below(63) as u32)), // power-of-two boundaries
                        _ => (rng.next_u64() >> (rng.u64_below(64) as u32)).max(1),
                    })
                    .collect::<Vec<u64>>()
            },
            |v| shrink_vec(v),
            |vals| {
                let mut w = BitWriter::new();
                for &v in vals {
                    w.put_gamma(v);
                }
                let payload_bits = w.bit_len();
                let buf = w.finish();
                // finish() pads the final byte with < 8 zero bits
                let padded = buf.len() * 8;
                if padded < payload_bits || padded - payload_bits >= 8 {
                    return false;
                }
                let mut r = BitReader::new(&buf);
                vals.iter().all(|&v| r.get_gamma() == Some(v))
                    && r.bit_pos() == payload_bits
            },
        );
    }

    #[test]
    fn prop_mixed_bits_and_gammas_roundtrip() {
        // The codec interleaves fixed-width fields, γ codes, and sign
        // bits; the bit cursor must stay exact across any interleaving.
        use crate::testing::prop::{check, shrink_vec, PropConfig};
        check(
            PropConfig { cases: 100, seed: 0xB171 },
            |rng| {
                let n = 1 + rng.usize_below(40);
                (0..n)
                    .map(|_| {
                        let width = 1 + rng.u64_below(32) as u32;
                        let value = rng.next_u64() & ((1u64 << width) - 1);
                        let gamma = 1 + rng.u64_below(1 << 20);
                        let sign = rng.bernoulli(0.5);
                        (width, value, gamma, sign)
                    })
                    .collect::<Vec<(u32, u64, u64, bool)>>()
            },
            |v| shrink_vec(v),
            |fields| {
                let mut w = BitWriter::new();
                for &(width, value, gamma, sign) in fields {
                    w.put_bits(value, width);
                    w.put_gamma(gamma);
                    w.put_bit(sign);
                }
                let buf = w.finish();
                let mut r = BitReader::new(&buf);
                fields.iter().all(|&(width, value, gamma, sign)| {
                    r.get_bits(width) == Some(value)
                        && r.get_gamma() == Some(gamma)
                        && r.get_bit() == Some(sign)
                })
            },
        );
    }
}
