//! Bit-level I/O and Elias-γ codes for the sketch codec.

/// MSB-first bit writer.
#[derive(Default, Debug)]
pub struct BitWriter {
    buf: Vec<u8>,
    cur: u8,
    nbits: u8,
}

impl BitWriter {
    /// Empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one bit.
    #[inline]
    pub fn put_bit(&mut self, bit: bool) {
        self.cur = (self.cur << 1) | bit as u8;
        self.nbits += 1;
        if self.nbits == 8 {
            self.buf.push(self.cur);
            self.cur = 0;
            self.nbits = 0;
        }
    }

    /// Append the low `n` bits of `v`, MSB first.
    pub fn put_bits(&mut self, v: u64, n: u32) {
        for i in (0..n).rev() {
            self.put_bit((v >> i) & 1 == 1);
        }
    }

    /// Elias-γ code of `v ≥ 1`: (⌊log₂v⌋ zeros) then v's binary digits.
    pub fn put_gamma(&mut self, v: u64) {
        debug_assert!(v >= 1);
        let nbits = 64 - v.leading_zeros();
        for _ in 0..nbits - 1 {
            self.put_bit(false);
        }
        self.put_bits(v, nbits);
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Finish (pad the final byte with zeros) and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.cur <<= 8 - self.nbits;
            self.buf.push(self.cur);
        }
        self.buf
    }
}

/// MSB-first bit reader.
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // bit position
}

impl<'a> BitReader<'a> {
    /// Read from a byte buffer.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Next bit; `None` past the end.
    #[inline]
    pub fn get_bit(&mut self) -> Option<bool> {
        let byte = self.buf.get(self.pos / 8)?;
        let bit = (byte >> (7 - self.pos % 8)) & 1 == 1;
        self.pos += 1;
        Some(bit)
    }

    /// Next `n` bits as an integer.
    pub fn get_bits(&mut self, n: u32) -> Option<u64> {
        let mut v = 0u64;
        for _ in 0..n {
            v = (v << 1) | self.get_bit()? as u64;
        }
        Some(v)
    }

    /// Decode one Elias-γ value.
    pub fn get_gamma(&mut self) -> Option<u64> {
        let mut zeros = 0u32;
        while !self.get_bit()? {
            zeros += 1;
            if zeros > 64 {
                return None;
            }
        }
        let rest = self.get_bits(zeros)?;
        Some((1u64 << zeros) | rest)
    }

    /// Current bit position.
    pub fn bit_pos(&self) -> usize {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bits_roundtrip() {
        let mut w = BitWriter::new();
        w.put_bits(0b101101, 6);
        w.put_bits(0xDEAD, 16);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.get_bits(6), Some(0b101101));
        assert_eq!(r.get_bits(16), Some(0xDEAD));
    }

    #[test]
    fn gamma_roundtrip_exhaustive_small() {
        let mut w = BitWriter::new();
        for v in 1..=300u64 {
            w.put_gamma(v);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for v in 1..=300u64 {
            assert_eq!(r.get_gamma(), Some(v));
        }
    }

    #[test]
    fn gamma_roundtrip_random_large() {
        let mut rng = Rng::new(0);
        let vals: Vec<u64> = (0..2000).map(|_| rng.u64_below(1 << 40) + 1).collect();
        let mut w = BitWriter::new();
        for &v in &vals {
            w.put_gamma(v);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for &v in &vals {
            assert_eq!(r.get_gamma(), Some(v));
        }
    }

    #[test]
    fn gamma_length_is_2floorlog_plus_1() {
        for (v, len) in [(1u64, 1usize), (2, 3), (3, 3), (4, 5), (255, 15), (256, 17)] {
            let mut w = BitWriter::new();
            w.put_gamma(v);
            assert_eq!(w.bit_len(), len, "v={v}");
        }
    }

    #[test]
    fn reader_stops_at_end() {
        let buf = [0xFFu8];
        let mut r = BitReader::new(&buf);
        assert_eq!(r.get_bits(8), Some(0xFF));
        assert_eq!(r.get_bit(), None);
    }
}
