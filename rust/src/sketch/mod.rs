//! Sketch representation, builders, and the compressed codec.
//!
//! A sketch is `B = (1/s)·Σ_ℓ B_ℓ` where each `B_ℓ` has a single non-zero
//! `A_ij/p_ij`. Aggregating repeated draws, every non-zero of `B` is
//! `B_ij = k_ij·A_ij/(s·p_ij)` with `Σ|k_ij| = s`. For the L1-family
//! distributions `p_ij = ρ_i·|A_ij|/‖A_(i)‖₁`, so
//! `B_ij = sign(A_ij)·k_ij·‖A_(i)‖₁/(s·ρ_i)` — the value is a *row
//! constant* times a small integer, which is what makes the sketch
//! compressible to a handful of bits per sample (§1 of the paper, codec in
//! [`encode`]).

pub mod bitio;
pub mod builder;
pub mod encode;

pub use builder::{sketch_offline, SketchPlan};
pub use encode::{
    decode_sketch, encode_sketch, row_group_index, row_group_index_h, EncodedSketch,
    PayloadHeader, SketchCursor,
};

use crate::sparse::{Coo, Csr};

/// One aggregated sketch sample.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SketchEntry {
    /// Row index.
    pub row: u32,
    /// Column index.
    pub col: u32,
    /// Multiplicity `k_ij ≥ 1` (number of times this entry was drawn).
    pub count: u32,
    /// The sketch value `B_ij = k_ij·A_ij/(s·p_ij)`.
    pub value: f64,
}

/// A sparse sketch `B` of a data matrix.
#[derive(Clone, Debug)]
pub struct Sketch {
    /// Rows of the sketched matrix.
    pub m: usize,
    /// Columns.
    pub n: usize,
    /// Total draws `s` (`Σ count`).
    pub s: u64,
    /// Aggregated samples, row-major sorted.
    pub entries: Vec<SketchEntry>,
    /// Per-row codec scale `‖A_(i)‖₁/(s·ρ_i)` when the distribution is in
    /// the L1 family (enables the compact encoding); `None` otherwise.
    pub row_scale: Option<Vec<f64>>,
    /// Name of the distribution that produced this sketch.
    pub method: String,
}

impl Sketch {
    /// Number of distinct non-zero coordinates.
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Materialize as CSR (for SVD / spectral evaluation).
    pub fn to_csr(&self) -> Csr {
        let mut coo = Coo::new(self.m, self.n);
        for e in &self.entries {
            coo.push(e.row, e.col, e.value as f32);
        }
        coo.to_csr()
    }

    /// Materialize as COO.
    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::new(self.m, self.n);
        for e in &self.entries {
            coo.push(e.row, e.col, e.value as f32);
        }
        coo
    }

    /// Sort entries row-major and merge duplicates (same coordinate drawn
    /// in different shards).
    pub fn normalize(&mut self) {
        self.entries
            .sort_unstable_by(|a, b| (a.row, a.col).cmp(&(b.row, b.col)));
        let mut out: Vec<SketchEntry> = Vec::with_capacity(self.entries.len());
        for e in self.entries.drain(..) {
            match out.last_mut() {
                Some(last) if last.row == e.row && last.col == e.col => {
                    last.count += e.count;
                    last.value += e.value;
                }
                _ => out.push(e),
            }
        }
        self.entries = out;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_merges() {
        let mut sk = Sketch {
            m: 2,
            n: 2,
            s: 5,
            entries: vec![
                SketchEntry { row: 1, col: 0, count: 2, value: 4.0 },
                SketchEntry { row: 0, col: 0, count: 1, value: 1.0 },
                SketchEntry { row: 1, col: 0, count: 2, value: 4.0 },
            ],
            row_scale: None,
            method: "test".into(),
        };
        sk.normalize();
        assert_eq!(sk.nnz(), 2);
        assert_eq!(sk.entries[1].count, 4);
        assert_eq!(sk.entries[1].value, 8.0);
    }

    #[test]
    fn to_csr_roundtrip_values() {
        let sk = Sketch {
            m: 2,
            n: 3,
            s: 3,
            entries: vec![
                SketchEntry { row: 0, col: 2, count: 1, value: -1.5 },
                SketchEntry { row: 1, col: 0, count: 2, value: 3.0 },
            ],
            row_scale: None,
            method: "test".into(),
        };
        let csr = sk.to_csr();
        assert_eq!(csr.nnz(), 2);
        let coo = csr.to_coo();
        assert!(coo.entries.iter().any(|e| e.row == 0 && e.col == 2 && e.val == -1.5));
    }
}
