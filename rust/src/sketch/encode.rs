//! The compressed sketch codec (§1 of the paper).
//!
//! For L1-family sketches every non-zero is `sign·k_ij·scale(i)` with
//! `scale(i) = ‖A_(i)‖₁/(s·ρ_i)`, so the encoder stores:
//!
//! * header: `m`, `n`, `s` and the `m` per-row f32 scales — O(m log n) bits;
//! * body, row-major: per occupied row, the row id delta (γ), the number
//!   of entries (γ), then per entry the column offset delta (γ), the
//!   multiplicity `k_ij` (γ) and the sign bit — O(s·log(n/s)) bits total.
//!
//! Generic sketches (L2 family, arbitrary values) fall back to storing a
//! f32 value per entry instead of (k, sign). [`EncodedSketch::bits_per_sample`]
//! is the §1 metric (paper: 5–22 bits/sample).

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::sketch::bitio::{BitReader, BitWriter};
use crate::util::SharedBytes;

use super::{Sketch, SketchEntry};

/// A serialized sketch.
#[derive(Clone, Debug)]
pub struct EncodedSketch {
    /// m, n, s (for reporting).
    pub m: usize,
    /// Columns.
    pub n: usize,
    /// Draws.
    pub s: u64,
    /// Header bits (row scales etc.).
    pub header_bits: usize,
    /// Body bits (offsets/counts/signs).
    pub body_bits: usize,
    /// The encoded payload, behind a shared buffer: cloning an
    /// `EncodedSketch` (or the `ServableSketch` holding it) is O(1) and
    /// never copies the payload — store loads can even alias a
    /// memory-mapped file directly.
    pub bytes: SharedBytes,
    /// Whether the compact row-scale form was used.
    pub compact: bool,
}

impl EncodedSketch {
    /// Total size in bits.
    pub fn total_bits(&self) -> usize {
        self.header_bits + self.body_bits
    }

    /// The §1 metric: total bits divided by the number of draws `s`.
    pub fn bits_per_sample(&self) -> f64 {
        self.total_bits() as f64 / self.s as f64
    }

    /// Body-only bits per sample (excludes the O(m log n) header that
    /// amortizes across sample budgets).
    pub fn body_bits_per_sample(&self) -> f64 {
        self.body_bits as f64 / self.s as f64
    }
}

/// Encode a sketch. Uses the compact row-constant form when
/// `sketch.row_scale` is present, the generic value form otherwise.
pub fn encode_sketch(sk: &Sketch) -> Result<EncodedSketch> {
    let mut w = BitWriter::new();
    let compact = sk.row_scale.is_some();
    // --- header ---
    w.put_bits(sk.m as u64, 32);
    w.put_bits(sk.n as u64, 32);
    w.put_bits(sk.s, 64);
    w.put_bit(compact);
    if let Some(scales) = &sk.row_scale {
        if scales.len() != sk.m {
            return Err(Error::shape("row_scale length != m"));
        }
        for &sc in scales {
            w.put_bits((sc as f32).to_bits() as u64, 32);
        }
    }
    let header_bits = w.bit_len();

    // --- body: row-major entries ---
    if !sk
        .entries
        .windows(2)
        .all(|p| matches!(p, [a, b] if (a.row, a.col) < (b.row, b.col)))
    {
        return Err(Error::invalid("sketch entries must be sorted row-major"));
    }
    // group by row
    let mut idx = 0usize;
    let mut prev_row = 0u64;
    w.put_gamma(count_rows(&sk.entries) as u64 + 1); // number of occupied rows + 1
    while let Some(first) = sk.entries.get(idx) {
        let row = first.row;
        let end = sk
            .entries
            .get(idx..)
            .unwrap_or(&[])
            .iter()
            .position(|e| e.row != row)
            .map(|p| idx + p)
            .unwrap_or(sk.entries.len());
        // row id delta (+1 so γ-codable)
        w.put_gamma(row as u64 - prev_row + 1);
        prev_row = row as u64;
        w.put_gamma((end - idx) as u64);
        let mut prev_col = 0u64;
        for e in sk.entries.get(idx..end).unwrap_or(&[]) {
            w.put_gamma(e.col as u64 - prev_col + 1);
            prev_col = e.col as u64;
            w.put_gamma(e.count as u64);
            if compact {
                w.put_bit(e.value < 0.0);
            } else {
                w.put_bits((e.value as f32).to_bits() as u64, 32);
            }
        }
        idx = end;
    }
    let body_bits = w.bit_len() - header_bits;
    Ok(EncodedSketch {
        m: sk.m,
        n: sk.n,
        s: sk.s,
        header_bits,
        body_bits,
        bytes: w.finish().into(),
        compact,
    })
}

fn count_rows(entries: &[SketchEntry]) -> usize {
    let mut rows = 0;
    let mut last = u32::MAX;
    for e in entries {
        if e.row != last {
            rows += 1;
            last = e.row;
        }
    }
    rows
}

/// The parsed payload header: everything [`SketchCursor::open`] reads
/// before the first row group, in decoded form. Parsing it is O(m) for
/// compact payloads (the m-entry row-scale table), which ROADMAP flags as
/// dominating row/top-k latency on tall matrices when repeated per query —
/// so the serving layer parses once, caches the result, and opens cursors
/// through [`SketchCursor::with_header`] instead. The scale table sits
/// behind an [`Arc`] so cached headers clone in O(1).
#[derive(Clone, Debug)]
pub struct PayloadHeader {
    /// Rows of the sketched matrix.
    pub m: usize,
    /// Columns.
    pub n: usize,
    /// Total draws `s`.
    pub s: u64,
    /// Whether the compact row-scale form was used.
    pub compact: bool,
    /// Occupied row groups in the body.
    pub rows: usize,
    /// Bit offset of the first row group (end of the header fields).
    pub body_start: usize,
    row_scale: Option<Arc<Vec<f64>>>,
}

impl PayloadHeader {
    /// Decode the header fields of `enc`'s payload.
    pub fn parse(enc: &EncodedSketch) -> Result<PayloadHeader> {
        let mut r = BitReader::new(&enc.bytes);
        let m = r.get_bits(32).ok_or_else(truncated)? as usize;
        let n = r.get_bits(32).ok_or_else(truncated)? as usize;
        let s = r.get_bits(64).ok_or_else(truncated)?;
        let compact = r.get_bit().ok_or_else(truncated)?;
        let row_scale = if compact {
            let mut scales = Vec::with_capacity(m);
            for _ in 0..m {
                let bits = r.get_bits(32).ok_or_else(truncated)? as u32;
                scales.push(f32::from_bits(bits) as f64);
            }
            Some(Arc::new(scales))
        } else {
            None
        };
        let rows = (r.get_gamma().ok_or_else(truncated)? - 1) as usize;
        Ok(PayloadHeader {
            m,
            n,
            s,
            compact,
            rows,
            body_start: r.bit_pos(),
            row_scale,
        })
    }

    /// Per-row codec scales (present iff `compact`).
    pub fn row_scale(&self) -> Option<&[f64]> {
        self.row_scale.as_deref().map(|v| v.as_slice())
    }
}

/// A streaming decoder over an [`EncodedSketch`]'s payload: yields entries
/// in row-major order straight off the Elias-γ bit stream, without ever
/// materializing a [`Sketch`]. This is what the serving layer
/// ([`crate::serve`]) runs matvec/top-k queries on; [`decode_sketch`] is a
/// thin collect over it, so both paths share one decode semantics.
pub struct SketchCursor<'a> {
    reader: BitReader<'a>,
    /// Rows of the sketched matrix.
    pub m: usize,
    /// Columns.
    pub n: usize,
    /// Total draws `s`.
    pub s: u64,
    /// Whether the compact row-scale form was used.
    pub compact: bool,
    row_scale: Option<Arc<Vec<f64>>>,
    rows_left: usize,
    row_entries_left: usize,
    prev_row: u64,
    prev_col: u64,
}

fn truncated() -> Error {
    Error::Parse("truncated sketch".into())
}

impl<'a> SketchCursor<'a> {
    /// Decode the header and position the cursor at the first entry.
    pub fn open(enc: &'a EncodedSketch) -> Result<SketchCursor<'a>> {
        let header = PayloadHeader::parse(enc)?;
        Ok(Self::with_header(enc, &header))
    }

    /// Position a cursor at the first entry using an already-parsed
    /// header — O(1), no re-read of the m-entry scale table. The caller
    /// guarantees `header` was parsed from this `enc`.
    pub fn with_header(enc: &'a EncodedSketch, header: &PayloadHeader) -> SketchCursor<'a> {
        SketchCursor {
            reader: BitReader::new_at(&enc.bytes, header.body_start),
            m: header.m,
            n: header.n,
            s: header.s,
            compact: header.compact,
            row_scale: header.row_scale.clone(),
            rows_left: header.rows,
            row_entries_left: 0,
            prev_row: 0,
            prev_col: 0,
        }
    }

    /// Position a cursor at one row group whose first bit is `bit_offset`
    /// into the payload, with `prev_row` the row id of the *previous*
    /// group (0 for the first). Exactly one group is yielded, then a clean
    /// end — this is the O(1) row-slice seek behind the store's per-row
    /// offset index.
    pub fn row_group_at(
        enc: &'a EncodedSketch,
        header: &PayloadHeader,
        bit_offset: u64,
        prev_row: u32,
    ) -> SketchCursor<'a> {
        SketchCursor {
            reader: BitReader::new_at(&enc.bytes, bit_offset as usize),
            m: header.m,
            n: header.n,
            s: header.s,
            compact: header.compact,
            row_scale: header.row_scale.clone(),
            rows_left: 1,
            row_entries_left: 0,
            prev_row: prev_row as u64,
            prev_col: 0,
        }
    }

    /// Position a cursor over the contiguous row-group window
    /// `index[lo..hi]` of the per-row offset `index` (as produced by
    /// [`row_group_index`]): seek to group `lo`'s first bit, decode
    /// exactly `hi - lo` groups, then end cleanly. This is the
    /// **row-range plan** behind row-parallel serving — each worker
    /// decodes one disjoint window and the partial results are reduced
    /// in window order, so the combined answer is bit-identical to one
    /// sequential scan. `lo == hi` yields an immediately-empty cursor.
    pub fn row_range(
        enc: &'a EncodedSketch,
        header: &PayloadHeader,
        index: &[(u32, u64)],
        lo: usize,
        hi: usize,
    ) -> SketchCursor<'a> {
        debug_assert!(lo <= hi && hi <= index.len(), "row_range {lo}..{hi} of {}", index.len());
        let first = if lo < hi { index.get(lo) } else { None };
        let (bit_offset, prev_row) = match first {
            // empty window: clean immediate end
            None => (enc.bytes.len() * 8, 0),
            Some(&(_, start_bit)) => (
                start_bit as usize,
                lo.checked_sub(1).and_then(|p| index.get(p)).map_or(0, |g| g.0),
            ),
        };
        SketchCursor {
            reader: BitReader::new_at(&enc.bytes, bit_offset),
            m: header.m,
            n: header.n,
            s: header.s,
            compact: header.compact,
            row_scale: header.row_scale.clone(),
            rows_left: hi.min(index.len()).saturating_sub(lo),
            row_entries_left: 0,
            prev_row: prev_row as u64,
            prev_col: 0,
        }
    }

    /// Per-row codec scales (present iff `compact`).
    pub fn row_scale(&self) -> Option<&[f64]> {
        self.row_scale.as_deref().map(|v| v.as_slice())
    }

    /// Next decoded entry, row-major; `Ok(None)` at a clean end. A payload
    /// that runs out mid-entry surfaces as `Error::Parse`, never a silent
    /// truncation.
    pub fn next_entry(&mut self) -> Result<Option<SketchEntry>> {
        if self.row_entries_left == 0 {
            if self.rows_left == 0 {
                return Ok(None);
            }
            self.rows_left -= 1;
            self.prev_row += self.reader.get_gamma().ok_or_else(truncated)? - 1;
            self.row_entries_left = self.reader.get_gamma().ok_or_else(truncated)? as usize;
            if self.row_entries_left == 0 {
                return Err(Error::Parse("empty row group in sketch payload".into()));
            }
            self.prev_col = 0;
        }
        self.row_entries_left -= 1;
        self.prev_col += self.reader.get_gamma().ok_or_else(truncated)? - 1;
        let row = self.prev_row;
        let col = self.prev_col;
        let k = self.reader.get_gamma().ok_or_else(truncated)? as u32;
        let value = if self.compact {
            let neg = self.reader.get_bit().ok_or_else(truncated)?;
            let scale = *self
                .row_scale
                .as_ref()
                .and_then(|sc| sc.get(row as usize))
                .ok_or_else(|| Error::Parse(format!("row {row} outside scale table")))?;
            let v = k as f64 * scale;
            if neg {
                -v
            } else {
                v
            }
        } else {
            let bits = self.reader.get_bits(32).ok_or_else(truncated)? as u32;
            f32::from_bits(bits) as f64
        };
        Ok(Some(SketchEntry { row: row as u32, col: col as u32, count: k, value }))
    }
}

/// Decode an encoded sketch (exact inverse of [`encode_sketch`] up to f32
/// rounding of values/scales).
pub fn decode_sketch(enc: &EncodedSketch, method: &str) -> Result<Sketch> {
    let mut cur = SketchCursor::open(enc)?;
    let mut entries = Vec::new();
    while let Some(e) = cur.next_entry()? {
        entries.push(e);
    }
    let SketchCursor { m, n, s, row_scale, .. } = cur;
    let row_scale = row_scale.map(|a| Arc::try_unwrap(a).unwrap_or_else(|a| (*a).clone()));
    Ok(Sketch { m, n, s, entries, row_scale, method: method.to_string() })
}

/// Walk the payload body once and record, for every occupied row group,
/// `(row id, bit offset of the group's first bit)`. Feeding an offset and
/// the *previous* group's row id into [`SketchCursor::row_group_at`]
/// decodes that one group without touching the rest of the payload —
/// the store appends this table to `.msk` files for O(1) row-slice seeks.
pub fn row_group_index(enc: &EncodedSketch) -> Result<Vec<(u32, u64)>> {
    let header = PayloadHeader::parse(enc)?;
    row_group_index_h(enc, &header)
}

/// [`row_group_index`] with a pre-parsed payload header — callers that
/// already hold one (e.g. [`crate::serve::ServableSketch`] loading) skip
/// a second O(m) header decode.
pub fn row_group_index_h(enc: &EncodedSketch, header: &PayloadHeader) -> Result<Vec<(u32, u64)>> {
    let mut r = BitReader::new_at(&enc.bytes, header.body_start);
    let mut out = Vec::with_capacity(header.rows);
    let mut prev_row = 0u64;
    for _ in 0..header.rows {
        let group_start = r.bit_pos() as u64;
        prev_row += r.get_gamma().ok_or_else(truncated)? - 1;
        if prev_row >= header.m as u64 {
            return Err(Error::Parse(format!(
                "sketch payload row {prev_row} outside {} rows",
                header.m
            )));
        }
        out.push((prev_row as u32, group_start));
        let count = r.get_gamma().ok_or_else(truncated)?;
        if count == 0 {
            return Err(Error::Parse("empty row group in sketch payload".into()));
        }
        for _ in 0..count {
            r.get_gamma().ok_or_else(truncated)?; // column delta
            r.get_gamma().ok_or_else(truncated)?; // multiplicity k
            if header.compact {
                r.get_bit().ok_or_else(truncated)?; // sign
            } else {
                r.get_bits(32).ok_or_else(truncated)?; // f32 value
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::DistributionKind;
    use crate::sketch::builder::{sketch_offline, SketchPlan};
    use crate::sparse::Coo;
    use crate::util::rng::Rng;

    fn random_csr(m: usize, n: usize, per_row: usize, seed: u64) -> crate::sparse::Csr {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(m, n);
        for i in 0..m {
            for _ in 0..per_row {
                coo.push(i as u32, rng.usize_below(n) as u32, rng.normal() as f32 + 0.1);
            }
        }
        coo.to_csr()
    }

    #[test]
    fn compact_roundtrip_exact() {
        let a = random_csr(32, 4096, 40, 0);
        let sk = sketch_offline(&a, &SketchPlan::new(DistributionKind::Bernstein, 3_000))
            .unwrap();
        let enc = encode_sketch(&sk).unwrap();
        assert!(enc.compact);
        let back = decode_sketch(&enc, &sk.method).unwrap();
        assert_eq!(back.entries.len(), sk.entries.len());
        for (a, b) in sk.entries.iter().zip(back.entries.iter()) {
            assert_eq!((a.row, a.col, a.count), (b.row, b.col, b.count));
            assert!((a.value - b.value).abs() <= a.value.abs() * 1e-6 + 1e-12);
        }
    }

    #[test]
    fn generic_roundtrip_exact() {
        let a = random_csr(16, 512, 30, 1);
        let sk = sketch_offline(&a, &SketchPlan::new(DistributionKind::L2, 1_000)).unwrap();
        let enc = encode_sketch(&sk).unwrap();
        assert!(!enc.compact);
        let back = decode_sketch(&enc, &sk.method).unwrap();
        assert_eq!(back.entries.len(), sk.entries.len());
        for (a, b) in sk.entries.iter().zip(back.entries.iter()) {
            assert_eq!((a.row, a.col, a.count), (b.row, b.col, b.count));
            assert!((a.value - b.value).abs() <= a.value.abs() * 1e-6);
        }
    }

    #[test]
    fn compact_beats_coo_list_format() {
        // §1 claim: compact form ≪ 96-bit-per-entry row-column-value COO.
        let a = random_csr(64, 65_536, 100, 2);
        let sk = sketch_offline(
            &a,
            &SketchPlan::new(DistributionKind::Bernstein, 20_000).with_seed(3),
        )
        .unwrap();
        let enc = encode_sketch(&sk).unwrap();
        let coo_bits = sk.nnz() * 96;
        assert!(
            enc.total_bits() < coo_bits / 2,
            "codec {} bits vs COO {} bits",
            enc.total_bits(),
            coo_bits
        );
        // body bits/sample in the paper's reported 5–22 range
        let bps = enc.body_bits_per_sample();
        assert!((2.0..40.0).contains(&bps), "bits/sample={bps}");
    }

    #[test]
    fn cached_header_cursor_matches_cold_open() {
        for (kind, seed) in [(DistributionKind::Bernstein, 4u64), (DistributionKind::L2, 5)] {
            let a = random_csr(24, 1024, 30, seed);
            let sk = sketch_offline(&a, &SketchPlan::new(kind, 2_000)).unwrap();
            let enc = encode_sketch(&sk).unwrap();
            let header = PayloadHeader::parse(&enc).unwrap();
            assert_eq!((header.m, header.n, header.s), (enc.m, enc.n, enc.s));
            assert_eq!(header.compact, enc.compact);
            assert_eq!(header.row_scale().is_some(), enc.compact);

            let mut cold = SketchCursor::open(&enc).unwrap();
            let mut warm = SketchCursor::with_header(&enc, &header);
            loop {
                let a = cold.next_entry().unwrap();
                let b = warm.next_entry().unwrap();
                assert_eq!(a, b);
                if a.is_none() {
                    break;
                }
            }
        }
    }

    #[test]
    fn row_group_index_seeks_to_every_row() {
        for kind in [DistributionKind::Bernstein, DistributionKind::L2] {
            let a = random_csr(40, 2048, 25, 7);
            let sk = sketch_offline(&a, &SketchPlan::new(kind, 3_000).with_seed(9)).unwrap();
            let enc = encode_sketch(&sk).unwrap();
            let header = PayloadHeader::parse(&enc).unwrap();
            let index = row_group_index(&enc).unwrap();
            assert_eq!(index.len(), header.rows);
            assert!(index.windows(2).all(|w| w[0].0 < w[1].0), "rows ascending");

            let dec = decode_sketch(&enc, &sk.method).unwrap();
            for (pos, &(row, off)) in index.iter().enumerate() {
                let prev_row = if pos == 0 { 0 } else { index[pos - 1].0 };
                let mut cur = SketchCursor::row_group_at(&enc, &header, off, prev_row);
                let mut got = Vec::new();
                while let Some(e) = cur.next_entry().unwrap() {
                    got.push(e);
                }
                let want: Vec<SketchEntry> =
                    dec.entries.iter().copied().filter(|e| e.row == row).collect();
                assert_eq!(got, want, "{kind:?} row {row}");
            }
        }
    }

    #[test]
    fn row_range_windows_match_filtered_decode() {
        for kind in [DistributionKind::Bernstein, DistributionKind::L2] {
            let a = random_csr(40, 2048, 25, 11);
            let sk = sketch_offline(&a, &SketchPlan::new(kind, 3_000).with_seed(4)).unwrap();
            let enc = encode_sketch(&sk).unwrap();
            let header = PayloadHeader::parse(&enc).unwrap();
            let index = row_group_index(&enc).unwrap();
            let dec = decode_sketch(&enc, &sk.method).unwrap();
            let g = index.len();
            for (lo, hi) in
                [(0, g), (0, 0), (g, g), (0, 1), (g - 1, g), (1, g / 2), (g / 2, g)]
            {
                let mut cur = SketchCursor::row_range(&enc, &header, &index, lo, hi);
                let mut got = Vec::new();
                while let Some(e) = cur.next_entry().unwrap() {
                    got.push(e);
                }
                let rows: Vec<u32> = index[lo..hi].iter().map(|&(r, _)| r).collect();
                let want: Vec<SketchEntry> = dec
                    .entries
                    .iter()
                    .copied()
                    .filter(|e| rows.contains(&e.row))
                    .collect();
                assert_eq!(got, want, "{kind:?} window {lo}..{hi}");
            }
        }
    }

    #[test]
    fn empty_sketch_roundtrips() {
        let sk = crate::sketch::Sketch {
            m: 4,
            n: 4,
            s: 1,
            entries: vec![],
            row_scale: None,
            method: "t".into(),
        };
        let enc = encode_sketch(&sk).unwrap();
        let back = decode_sketch(&enc, "t").unwrap();
        assert!(back.entries.is_empty());
        assert_eq!(back.m, 4);
    }
}
