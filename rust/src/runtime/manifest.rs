//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust runtime. The runtime is entirely manifest-driven: op names,
//! file names, and block shapes all come from here.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// One compiled artifact.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    /// Op name (`gram`, `apply`, `proj`, `probs_l1`, `probs_l2`,
    /// `power_iter`, `subspace_round`).
    pub op: String,
    /// HLO text file (relative to the artifact dir).
    pub file: String,
    /// Block rows R.
    pub rows: usize,
    /// Subspace width K.
    pub k: usize,
    /// Dense column block C.
    pub cols: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Artifact directory.
    pub dir: PathBuf,
    /// All entries.
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Artifact(format!("cannot read {}: {e}", path.display())))?;
        let v = Json::parse(&text)?;
        let version = v
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::Artifact("manifest missing version".into()))?;
        if version != 1 {
            return Err(Error::Artifact(format!("unsupported manifest version {version}")));
        }
        let mut entries = Vec::new();
        for e in v
            .get("entries")
            .ok_or_else(|| Error::Artifact("manifest missing entries".into()))?
            .items()
        {
            let field = |name: &str| -> Result<usize> {
                e.get(name)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| Error::Artifact(format!("entry missing {name}")))
            };
            entries.push(ArtifactEntry {
                op: e
                    .get("op")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::Artifact("entry missing op".into()))?
                    .to_string(),
                file: e
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::Artifact("entry missing file".into()))?
                    .to_string(),
                rows: field("rows")?,
                k: field("k")?,
                cols: field("cols")?,
            });
        }
        if entries.is_empty() {
            return Err(Error::Artifact("manifest has no entries".into()));
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// All variants of one op, sorted ascending by block rows.
    pub fn variants(&self, op: &str) -> Vec<&ArtifactEntry> {
        let mut v: Vec<&ArtifactEntry> = self.entries.iter().filter(|e| e.op == op).collect();
        v.sort_by_key(|e| e.rows);
        v
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, e: &ArtifactEntry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("matsketch_manifest_ok");
        write_manifest(
            &dir,
            r#"{"version": 1, "entries": [
                {"op": "gram", "file": "g.hlo.txt", "rows": 2048, "k": 32, "cols": 512},
                {"op": "gram", "file": "g2.hlo.txt", "rows": 256, "k": 32, "cols": 512}
            ]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let vs = m.variants("gram");
        assert_eq!(vs.len(), 2);
        assert_eq!(vs[0].rows, 256); // sorted ascending
        assert!(m.variants("nope").is_empty());
    }

    #[test]
    fn rejects_bad_version_and_missing_fields() {
        let dir = std::env::temp_dir().join("matsketch_manifest_bad1");
        write_manifest(&dir, r#"{"version": 9, "entries": []}"#);
        assert!(Manifest::load(&dir).is_err());
        let dir2 = std::env::temp_dir().join("matsketch_manifest_bad2");
        write_manifest(&dir2, r#"{"version": 1, "entries": [{"op": "gram"}]}"#);
        assert!(Manifest::load(&dir2).is_err());
    }

    #[test]
    fn missing_file_errors() {
        let dir = std::env::temp_dir().join("matsketch_manifest_nofile");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(Manifest::load(&dir).is_err());
    }
}
