//! Pure-Rust [`DenseEngine`] — used when artifacts are absent and as the
//! cross-validation oracle for [`super::XlaEngine`] in tests.

use crate::error::Result;
use crate::linalg::dense_ops;
use crate::sparse::Dense;

use super::DenseEngine;

/// Dependency-free engine backed by `linalg::dense_ops`.
pub struct RustEngine;

impl DenseEngine for RustEngine {
    fn name(&self) -> &'static str {
        "rust"
    }

    fn gram(&self, y: &Dense) -> Result<Vec<f64>> {
        Ok(dense_ops::gram(y))
    }

    fn apply(&self, y: &Dense, t: &[f64]) -> Result<Dense> {
        Ok(dense_ops::apply_factor(y, t))
    }

    fn proj(&self, q: &Dense, a: &Dense) -> Result<Dense> {
        Ok(dense_ops::proj(q, a))
    }

    fn power_iter(&self, g: &[f64], k: usize) -> Result<(f64, Vec<f64>)> {
        assert_eq!(g.len(), k * k);
        // Fixed-trip-count power iteration, mirroring the AOT graph.
        let mut v = vec![1.0f64 / (k as f64).sqrt(); k];
        let mut lam = 0.0f64;
        for _ in 0..96 {
            let mut w = vec![0.0f64; k];
            for i in 0..k {
                let gi = &g[i * k..(i + 1) * k];
                w[i] = gi.iter().zip(v.iter()).map(|(a, b)| a * b).sum();
            }
            lam = w.iter().map(|x| x * x).sum::<f64>().sqrt();
            if lam <= 1e-300 {
                return Ok((0.0, v));
            }
            for (vi, wi) in v.iter_mut().zip(w.iter()) {
                *vi = wi / lam;
            }
        }
        Ok((lam, v))
    }

    fn probs(&self, a: &Dense, w: &[f32], power: u8) -> Result<Dense> {
        assert_eq!(w.len(), a.rows);
        let mut out = Dense::zeros(a.rows, a.cols);
        for i in 0..a.rows {
            let wi = w[i];
            let src = a.row(i);
            let dst = out.row_mut(i);
            match power {
                1 => {
                    for (d, s) in dst.iter_mut().zip(src.iter()) {
                        *d = wi * s.abs();
                    }
                }
                2 => {
                    for (d, s) in dst.iter_mut().zip(src.iter()) {
                        *d = wi * s * s;
                    }
                }
                p => panic!("probs power must be 1 or 2, got {p}"),
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn power_iter_on_diagonal() {
        let g = vec![2.0, 0.0, 0.0, 9.0];
        let (lam, v) = RustEngine.power_iter(&g, 2).unwrap();
        assert!((lam - 9.0).abs() < 1e-9);
        assert!(v[1].abs() > 0.999);
    }

    #[test]
    fn probs_powers() {
        let a = Dense::from_rows(&[&[-2.0, 3.0], &[1.0, -1.0]]);
        let w = [0.5f32, 2.0];
        let p1 = RustEngine.probs(&a, &w, 1).unwrap();
        assert_eq!(p1.data, vec![1.0, 1.5, 2.0, 2.0]);
        let p2 = RustEngine.probs(&a, &w, 2).unwrap();
        assert_eq!(p2.data, vec![2.0, 4.5, 2.0, 2.0]);
    }

    #[test]
    fn engine_round_trip_orthonormalizes() {
        let mut rng = Rng::new(1);
        let y = Dense::randn(300, 6, &mut rng);
        let q = crate::linalg::svd::orthonormalize(&y, &RustEngine).unwrap();
        let g = RustEngine.gram(&q).unwrap();
        assert!(dense_ops::max_offdiag_dev_from_identity(&g, 6) < 1e-4);
    }
}
