//! AOT runtime: load the JAX/Pallas-lowered HLO artifacts and run them on
//! the PJRT CPU client (`xla` crate). Python never runs here — artifacts
//! are produced once by `make artifacts`.
//!
//! All consumers (SVD, quality metrics, probability tables) are written
//! against the [`DenseEngine`] trait; [`XlaEngine`] executes the artifacts
//! (requires the `pjrt` cargo feature + the vendored `xla` crate — a stub
//! that always falls back otherwise), [`RustEngine`] is the
//! dependency-free fallback, and tests cross-validate the two.

pub mod engine;
pub mod fallback;
pub mod manifest;

pub use engine::XlaEngine;
pub use fallback::RustEngine;
pub use manifest::{ArtifactEntry, Manifest};

use crate::error::Result;
use crate::sparse::Dense;

/// Dense block-compute engine: the operations the AOT artifacts implement.
///
/// Shapes are caller-natural (any rows/k/c); engines are responsible for
/// padding to their internal block shapes (padding with zero rows/columns
/// is exact for every op here — covered by `python/tests/test_kernels.py`
/// and `rust/tests/integration_runtime.rs`).
pub trait DenseEngine: Send + Sync {
    /// Engine name for logs/reports.
    fn name(&self) -> &'static str;

    /// Gram matrix `G = YᵀY` (row-major k×k, f64).
    fn gram(&self, y: &Dense) -> Result<Vec<f64>>;

    /// `Q = Y·T` for a small k×k factor `T` (row-major f64).
    fn apply(&self, y: &Dense, t: &[f64]) -> Result<Dense>;

    /// Projection coefficients `P = Qᵀ·A` (k×c).
    fn proj(&self, q: &Dense, a: &Dense) -> Result<Dense>;

    /// Dominant eigenpair of a small symmetric PSD matrix (row-major k×k).
    fn power_iter(&self, g: &[f64], k: usize) -> Result<(f64, Vec<f64>)>;

    /// Entrywise probability table `p_ij = w_i·|a_ij|^power`, `power ∈ {1,2}`.
    fn probs(&self, a: &Dense, w: &[f32], power: u8) -> Result<Dense>;
}

/// Pick the best available engine: XLA artifacts if present (directory from
/// `MATSKETCH_ARTIFACTS`, default `artifacts/`), otherwise the Rust
/// fallback.
pub fn default_engine() -> Box<dyn DenseEngine> {
    let dir = std::env::var("MATSKETCH_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    match XlaEngine::from_dir(std::path::Path::new(&dir)) {
        Ok(e) => Box::new(e),
        Err(err) => {
            crate::warn_log!("XLA engine unavailable ({err}); using Rust fallback");
            Box::new(RustEngine)
        }
    }
}
