//! [`XlaEngine`] — executes the AOT HLO artifacts on the PJRT CPU client.
//!
//! The real implementation needs the `xla` crate (PJRT bindings), which is
//! only present in builds with the `pjrt` feature enabled. Default builds
//! get a stub whose constructor always fails, so [`super::default_engine`]
//! falls back to the pure-Rust [`super::RustEngine`]; every consumer is
//! written against the [`super::DenseEngine`] trait and never notices.

#[cfg(feature = "pjrt")]
pub use pjrt_impl::XlaEngine;

#[cfg(not(feature = "pjrt"))]
pub use stub::XlaEngine;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::Path;

    use crate::error::{Error, Result};
    use crate::runtime::DenseEngine;
    use crate::sparse::Dense;

    /// Stand-in for the PJRT-backed engine in builds without the `pjrt`
    /// feature. [`XlaEngine::from_dir`] always fails, so callers fall back
    /// to [`crate::runtime::RustEngine`]; the `DenseEngine` impl exists
    /// only so the two engines stay interchangeable at the type level.
    pub struct XlaEngine {
        _private: (),
    }

    fn unavailable() -> Error {
        Error::Artifact(
            "matsketch was built without the `pjrt` feature; \
             XLA artifacts cannot be loaded (the Rust fallback engine is used instead)"
                .into(),
        )
    }

    impl XlaEngine {
        /// Always fails in non-`pjrt` builds.
        pub fn from_dir(_dir: &Path) -> Result<XlaEngine> {
            Err(unavailable())
        }
    }

    impl DenseEngine for XlaEngine {
        fn name(&self) -> &'static str {
            "xla-unavailable"
        }
        fn gram(&self, _y: &Dense) -> Result<Vec<f64>> {
            Err(unavailable())
        }
        fn apply(&self, _y: &Dense, _t: &[f64]) -> Result<Dense> {
            Err(unavailable())
        }
        fn proj(&self, _q: &Dense, _a: &Dense) -> Result<Dense> {
            Err(unavailable())
        }
        fn power_iter(&self, _g: &[f64], _k: usize) -> Result<(f64, Vec<f64>)> {
            Err(unavailable())
        }
        fn probs(&self, _a: &Dense, _w: &[f32], _power: u8) -> Result<Dense> {
            Err(unavailable())
        }
    }
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    //! One `PjRtLoadedExecutable` is compiled per manifest entry at
    //! construction and cached for the life of the engine. Callers use
    //! natural shapes; this module windows rows into the artifact block
    //! size (accumulating across windows for reductions) and zero-pads
    //! `k`/`c` to the artifact dimensions — padding is exact for every op
    //! (zero rows and columns contribute nothing to Gram/projection sums,
    //! and the padded power-iteration dimensions carry eigenvalue 0).

    use std::collections::HashMap;
    use std::path::Path;

    use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

    use crate::error::{Error, Result};
    use crate::runtime::manifest::{ArtifactEntry, Manifest};
    use crate::runtime::DenseEngine;
    use crate::sparse::Dense;

    struct Compiled {
        entry: ArtifactEntry,
        exe: PjRtLoadedExecutable,
    }

    /// PJRT-backed engine. `Send + Sync`: the PJRT CPU client serializes
    /// executions internally; matsketch only calls it from one evaluation
    /// thread at a time.
    pub struct XlaEngine {
        _client: PjRtClient,
        /// op name → variants sorted by ascending block rows.
        ops: HashMap<String, Vec<Compiled>>,
    }

    // SAFETY: the xla crate wraps raw pointers without Send/Sync markers; the
    // PJRT CPU client is thread-compatible and matsketch confines engine use to
    // a single thread at a time (benches/eval drive it sequentially).
    unsafe impl Send for XlaEngine {}
    unsafe impl Sync for XlaEngine {}

    impl XlaEngine {
        /// Load every artifact in `dir` (per its manifest) and compile.
        pub fn from_dir(dir: &Path) -> Result<XlaEngine> {
            let manifest = Manifest::load(dir)?;
            let client = PjRtClient::cpu()?;
            let mut ops: HashMap<String, Vec<Compiled>> = HashMap::new();
            for entry in &manifest.entries {
                let path = manifest.path_of(entry);
                let proto = HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| Error::Artifact("non-utf8 path".into()))?,
                )?;
                let comp = XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp)?;
                ops.entry(entry.op.clone())
                    .or_default()
                    .push(Compiled { entry: entry.clone(), exe });
            }
            for v in ops.values_mut() {
                v.sort_by_key(|c| c.entry.rows);
            }
            crate::info!(
                "XlaEngine: compiled {} artifacts from {}",
                manifest.entries.len(),
                dir.display()
            );
            Ok(XlaEngine { _client: client, ops })
        }

        /// Pick the variant with the least padding waste for `rows`.
        fn pick(&self, op: &str, rows: usize) -> Result<&Compiled> {
            let vs = self
                .ops
                .get(op)
                .ok_or_else(|| Error::Artifact(format!("no artifact for op {op}")))?;
            Ok(vs
                .iter()
                .find(|c| c.entry.rows >= rows)
                .unwrap_or_else(|| vs.last().unwrap()))
        }

        fn literal_2d(data: &[f32], rows: usize, cols: usize) -> Result<Literal> {
            debug_assert_eq!(data.len(), rows * cols);
            Ok(Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
        }

        fn run(&self, c: &Compiled, args: &[&Literal]) -> Result<Vec<Literal>> {
            let result = c.exe.execute::<&Literal>(args)?;
            let lit = result[0][0].to_literal_sync()?;
            Ok(lit.to_tuple()?)
        }

        /// Pad `src` (rows×cols) into shape (rows_pad×cols_pad), zero-filled.
        fn pad_block(src: &Dense, r0: usize, rows_pad: usize, cols_pad: usize) -> Vec<f32> {
            let mut out = vec![0.0f32; rows_pad * cols_pad];
            let hi = (r0 + rows_pad).min(src.rows);
            for i in r0..hi {
                let srow = src.row(i);
                let take = srow.len().min(cols_pad);
                out[(i - r0) * cols_pad..(i - r0) * cols_pad + take]
                    .copy_from_slice(&srow[..take]);
            }
            out
        }
    }

    impl DenseEngine for XlaEngine {
        fn name(&self) -> &'static str {
            "xla"
        }

        fn gram(&self, y: &Dense) -> Result<Vec<f64>> {
            let k = y.cols;
            let c = self.pick("gram", y.rows)?;
            let (rr, kk) = (c.entry.rows, c.entry.k);
            if k > kk {
                return Err(Error::shape(format!("gram: k={k} exceeds artifact k={kk}")));
            }
            let mut acc = vec![0.0f64; kk * kk];
            let mut r0 = 0;
            while r0 < y.rows {
                let buf = Self::pad_block(y, r0, rr, kk);
                let lit = Self::literal_2d(&buf, rr, kk)?;
                let outs = self.run(c, &[&lit])?;
                let g: Vec<f32> = outs[0].to_vec()?;
                for (a, v) in acc.iter_mut().zip(g.iter()) {
                    *a += *v as f64;
                }
                r0 += rr;
            }
            // slice kk×kk down to k×k
            let mut out = vec![0.0f64; k * k];
            for a in 0..k {
                for b in 0..k {
                    out[a * k + b] = acc[a * kk + b];
                }
            }
            Ok(out)
        }

        fn apply(&self, y: &Dense, t: &[f64]) -> Result<Dense> {
            let k = y.cols;
            assert_eq!(t.len(), k * k);
            let c = self.pick("apply", y.rows)?;
            let (rr, kk) = (c.entry.rows, c.entry.k);
            if k > kk {
                return Err(Error::shape(format!("apply: k={k} exceeds artifact k={kk}")));
            }
            // pad T to kk×kk (zero pad: extra output columns are zero, sliced off)
            let mut tpad = vec![0.0f32; kk * kk];
            for a in 0..k {
                for b in 0..k {
                    tpad[a * kk + b] = t[a * k + b] as f32;
                }
            }
            let t_lit = Self::literal_2d(&tpad, kk, kk)?;
            let mut out = Dense::zeros(y.rows, k);
            let mut r0 = 0;
            while r0 < y.rows {
                let buf = Self::pad_block(y, r0, rr, kk);
                let lit = Self::literal_2d(&buf, rr, kk)?;
                let outs = self.run(c, &[&lit, &t_lit])?;
                let q: Vec<f32> = outs[0].to_vec()?;
                let hi = (r0 + rr).min(y.rows);
                for i in r0..hi {
                    out.row_mut(i).copy_from_slice(&q[(i - r0) * kk..(i - r0) * kk + k]);
                }
                r0 += rr;
            }
            Ok(out)
        }

        fn proj(&self, q: &Dense, a: &Dense) -> Result<Dense> {
            assert_eq!(q.rows, a.rows);
            let (k, cols) = (q.cols, a.cols);
            let c = self.pick("proj", q.rows)?;
            let (rr, kk, cc) = (c.entry.rows, c.entry.k, c.entry.cols);
            if k > kk {
                return Err(Error::shape(format!("proj: k={k} exceeds artifact k={kk}")));
            }
            let mut out = Dense::zeros(k, cols);
            let mut c0 = 0;
            while c0 < cols {
                let cw = cc.min(cols - c0);
                let mut acc = vec![0.0f64; kk * cc];
                let mut r0 = 0;
                while r0 < q.rows {
                    let qbuf = Self::pad_block(q, r0, rr, kk);
                    // column-window of A, padded
                    let mut abuf = vec![0.0f32; rr * cc];
                    let hi = (r0 + rr).min(a.rows);
                    for i in r0..hi {
                        let srow = &a.row(i)[c0..c0 + cw];
                        abuf[(i - r0) * cc..(i - r0) * cc + cw].copy_from_slice(srow);
                    }
                    let q_lit = Self::literal_2d(&qbuf, rr, kk)?;
                    let a_lit = Self::literal_2d(&abuf, rr, cc)?;
                    let outs = self.run(c, &[&q_lit, &a_lit])?;
                    let p: Vec<f32> = outs[0].to_vec()?;
                    for (av, pv) in acc.iter_mut().zip(p.iter()) {
                        *av += *pv as f64;
                    }
                    r0 += rr;
                }
                for x in 0..k {
                    for j in 0..cw {
                        out.set(x, c0 + j, acc[x * cc + j] as f32);
                    }
                }
                c0 += cw;
            }
            Ok(out)
        }

        fn power_iter(&self, g: &[f64], k: usize) -> Result<(f64, Vec<f64>)> {
            assert_eq!(g.len(), k * k);
            let c = self.pick("power_iter", 0)?;
            let kk = c.entry.k;
            if k > kk {
                return Err(Error::shape(format!("power_iter: k={k} exceeds artifact k={kk}")));
            }
            let mut gpad = vec![0.0f32; kk * kk];
            for a in 0..k {
                for b in 0..k {
                    gpad[a * kk + b] = g[a * k + b] as f32;
                }
            }
            // v0: ones on the live dimensions, zero on padding, so the padded
            // (eigenvalue-0) dimensions never mix in.
            let mut v0 = vec![0.0f32; kk];
            v0[..k].iter_mut().for_each(|x| *x = 1.0);
            let g_lit = Self::literal_2d(&gpad, kk, kk)?;
            let v_lit = Literal::vec1(&v0);
            let outs = self.run(c, &[&g_lit, &v_lit])?;
            let lam: Vec<f32> = outs[0].to_vec()?;
            let v: Vec<f32> = outs[1].to_vec()?;
            Ok((lam[0] as f64, v[..k].iter().map(|&x| x as f64).collect()))
        }

        fn probs(&self, a: &Dense, w: &[f32], power: u8) -> Result<Dense> {
            assert_eq!(w.len(), a.rows);
            let op = match power {
                1 => "probs_l1",
                2 => "probs_l2",
                p => return Err(Error::invalid(format!("probs power must be 1|2, got {p}"))),
            };
            let c = self.pick(op, a.rows)?;
            let (rr, cc) = (c.entry.rows, c.entry.cols);
            let mut out = Dense::zeros(a.rows, a.cols);
            let mut c0 = 0;
            while c0 < a.cols {
                let cw = cc.min(a.cols - c0);
                let mut r0 = 0;
                while r0 < a.rows {
                    let hi = (r0 + rr).min(a.rows);
                    let mut abuf = vec![0.0f32; rr * cc];
                    let mut wbuf = vec![0.0f32; rr];
                    for i in r0..hi {
                        abuf[(i - r0) * cc..(i - r0) * cc + cw]
                            .copy_from_slice(&a.row(i)[c0..c0 + cw]);
                        wbuf[i - r0] = w[i];
                    }
                    let a_lit = Self::literal_2d(&abuf, rr, cc)?;
                    let w_lit = Self::literal_2d(&wbuf, rr, 1)?;
                    let outs = self.run(c, &[&a_lit, &w_lit])?;
                    let p: Vec<f32> = outs[0].to_vec()?;
                    for i in r0..hi {
                        out.row_mut(i)[c0..c0 + cw]
                            .copy_from_slice(&p[(i - r0) * cc..(i - r0) * cc + cw]);
                    }
                    r0 += rr;
                }
                c0 += cw;
            }
            Ok(out)
        }
    }
}
