//! Top-k singular value decomposition by randomized subspace iteration.
//!
//! The quality metric of the paper's Figure 1 needs the top-k singular
//! vectors of both the original matrix `A` and each sketch `B`. This module
//! runs blocked subspace iteration where the FLOP-heavy tall-skinny
//! products go through a [`DenseEngine`] (XLA artifacts or pure-Rust
//! fallback) and the sparse products use [`Csr::spmm`]/[`Csr::spmm_t`].

use crate::error::Result;
use crate::linalg::cholesky::CholeskyQr;
use crate::linalg::jacobi::jacobi_eigh;
use crate::runtime::DenseEngine;
use crate::sparse::{Csr, Dense};
use crate::util::rng::Rng;

/// Result of [`topk_svd`]: `A ≈ U · diag(σ) · Vᵀ`.
#[derive(Clone, Debug)]
pub struct SvdResult {
    /// Left singular vectors, `m×k`, orthonormal columns.
    pub u: Dense,
    /// Singular values, descending.
    pub sigma: Vec<f64>,
    /// Right singular vectors, `n×k`, orthonormal columns.
    pub v: Dense,
}

/// Orthonormalize the columns of `y` in place via Cholesky-QR on `engine`.
pub fn orthonormalize(y: &Dense, engine: &dyn DenseEngine) -> Result<Dense> {
    let g = engine.gram(y)?;
    let cqr = CholeskyQr::from_gram(&g, y.cols)?;
    engine.apply(y, &cqr.t)
}

/// Top-`k` singular triplets of a sparse matrix by subspace iteration.
///
/// `iters` power rounds (each round applies `A·Aᵀ` once to the left basis);
/// 8–12 rounds are ample for the k=20 spectra in the paper's experiments.
pub fn topk_svd(
    a: &Csr,
    k: usize,
    iters: usize,
    seed: u64,
    engine: &dyn DenseEngine,
) -> Result<SvdResult> {
    let (m, n) = (a.m, a.n);
    let k = k.min(m).min(n);
    let mut rng = Rng::new(seed ^ 0x5bd1_e995);

    // Start from a random right basis and alternate:
    //   Y = A·V; Q = orth(Y); V = Aᵀ·Q; V = orth(V)
    let mut v = orthonormalize(&Dense::randn(n, k, &mut rng), engine)?;
    let mut q = Dense::zeros(m, k);
    for _ in 0..iters.max(1) {
        let y = a.spmm(&v);
        q = orthonormalize(&y, engine)?;
        let z = a.spmm_t(&q);
        v = orthonormalize(&z, engine)?;
    }

    // Rayleigh–Ritz on the converged right basis: Y = A·V, G = YᵀY.
    // G = Vᵀ AᵀA V = W diag(σ²) Wᵀ ⇒ σ, U = Y·W·diag(1/σ), V ← V·W.
    let y = a.spmm(&v);
    let g = engine.gram(&y)?;
    let (evals, w) = jacobi_eigh(&g, k);
    let sigma: Vec<f64> = evals.iter().map(|&e| e.max(0.0).sqrt()).collect();

    // U = Y · W · diag(1/σ)
    let mut w_scaled = w.clone();
    for r in 0..k {
        for c in 0..k {
            let s = sigma[c];
            w_scaled[r * k + c] = if s > 1e-300 { w[r * k + c] / s } else { 0.0 };
        }
    }
    let u = engine.apply(&y, &w_scaled)?;
    let v = engine.apply(&v, &w)?;
    let _ = q;
    Ok(SvdResult { u, sigma, v })
}

/// `‖A_k‖_F` — Frobenius mass of the best rank-k approximation
/// (√Σ₁ᵏ σᵢ²), from an [`SvdResult`].
pub fn rank_k_fro(svd: &SvdResult, k: usize) -> f64 {
    svd.sigma.iter().take(k).map(|s| s * s).sum::<f64>().sqrt()
}

/// Residual check used by tests: max column-wise relative error of
/// `A·vᵢ − σᵢ·uᵢ` for the first `k_check` triplets.
pub fn triplet_residual(a: &Csr, svd: &SvdResult, k_check: usize) -> f64 {
    let k = k_check.min(svd.sigma.len());
    let av = a.spmm(&svd.v);
    let mut worst: f64 = 0.0;
    for c in 0..k {
        let sigma = svd.sigma[c];
        if sigma <= 1e-12 {
            continue;
        }
        let mut err = 0.0f64;
        for i in 0..a.m {
            let d = av.get(i, c) as f64 - sigma * svd.u.get(i, c) as f64;
            err += d * d;
        }
        worst = worst.max(err.sqrt() / sigma);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense_ops;
    use crate::runtime::RustEngine;
    use crate::sparse::Coo;

    /// Dense low-rank-ish matrix with known spectrum: diag(σ) embedded in
    /// random orthogonal-ish bases.
    fn lowrank_csr(m: usize, n: usize, sigmas: &[f64], seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let engine = RustEngine;
        let k = sigmas.len();
        let u = orthonormalize(&Dense::randn(m, k, &mut rng), &engine).unwrap();
        let v = orthonormalize(&Dense::randn(n, k, &mut rng), &engine).unwrap();
        let mut coo = Coo::new(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut x = 0.0f64;
                for p in 0..k {
                    x += u.get(i, p) as f64 * sigmas[p] * v.get(j, p) as f64;
                }
                if x != 0.0 {
                    coo.push(i as u32, j as u32, x as f32);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn recovers_known_spectrum() {
        let sigmas = [40.0, 20.0, 8.0, 2.0];
        let a = lowrank_csr(60, 200, &sigmas, 7);
        let svd = topk_svd(&a, 4, 10, 1, &RustEngine).unwrap();
        for (got, want) in svd.sigma.iter().zip(sigmas.iter()) {
            assert!((got - want).abs() / want < 2e-2, "got={got} want={want}");
        }
        assert!(triplet_residual(&a, &svd, 4) < 1e-2);
    }

    #[test]
    fn bases_orthonormal() {
        let a = lowrank_csr(50, 120, &[10.0, 5.0, 1.0], 3);
        let svd = topk_svd(&a, 3, 8, 2, &RustEngine).unwrap();
        let gu = dense_ops::gram(&svd.u);
        let gv = dense_ops::gram(&svd.v);
        assert!(dense_ops::max_offdiag_dev_from_identity(&gu, 3) < 1e-3);
        assert!(dense_ops::max_offdiag_dev_from_identity(&gv, 3) < 1e-3);
    }

    #[test]
    fn rank_k_fro_partial_sums() {
        let svd = SvdResult {
            u: Dense::zeros(1, 2),
            sigma: vec![3.0, 4.0],
            v: Dense::zeros(1, 2),
        };
        assert!((rank_k_fro(&svd, 1) - 3.0).abs() < 1e-12);
        assert!((rank_k_fro(&svd, 2) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn k_clamped_to_shape() {
        let a = lowrank_csr(10, 30, &[5.0, 1.0], 11);
        let svd = topk_svd(&a, 50, 6, 4, &RustEngine).unwrap();
        assert_eq!(svd.sigma.len(), 10);
    }
}
