//! Cyclic Jacobi eigensolver for small symmetric matrices (f64).
//!
//! Used for the Rayleigh–Ritz step of the subspace-iteration SVD: the
//! projected k×k problem (k ≤ 32) is tiny, so the classic O(k³) sweep is
//! more than fast enough and has excellent accuracy.

/// Eigendecomposition of a symmetric k×k matrix (row-major).
/// Returns `(eigenvalues, eigenvectors)` sorted **descending**; the
/// eigenvector for `evals[c]` is the column `c` of the returned row-major
/// matrix (i.e. `evecs[r * k + c]`).
pub fn jacobi_eigh(a_in: &[f64], k: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a_in.len(), k * k);
    let mut a = a_in.to_vec();
    let mut v = vec![0.0f64; k * k];
    for i in 0..k {
        v[i * k + i] = 1.0;
    }
    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for i in 0..k {
            for j in (i + 1)..k {
                off += a[i * k + j] * a[i * k + j];
            }
        }
        let scale = (0..k).map(|i| a[i * k + i].abs()).fold(0.0f64, f64::max);
        if off.sqrt() <= 1e-14 * scale.max(1e-300) {
            break;
        }
        for p in 0..k {
            for q in (p + 1)..k {
                let apq = a[p * k + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[p * k + p];
                let aqq = a[q * k + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p and q of A
                for i in 0..k {
                    let aip = a[i * k + p];
                    let aiq = a[i * k + q];
                    a[i * k + p] = c * aip - s * aiq;
                    a[i * k + q] = s * aip + c * aiq;
                }
                for j in 0..k {
                    let apj = a[p * k + j];
                    let aqj = a[q * k + j];
                    a[p * k + j] = c * apj - s * aqj;
                    a[q * k + j] = s * apj + c * aqj;
                }
                // accumulate eigenvectors
                for i in 0..k {
                    let vip = v[i * k + p];
                    let viq = v[i * k + q];
                    v[i * k + p] = c * vip - s * viq;
                    v[i * k + q] = s * vip + c * viq;
                }
            }
        }
    }
    // extract + sort descending
    let mut order: Vec<usize> = (0..k).collect();
    let evals: Vec<f64> = (0..k).map(|i| a[i * k + i]).collect();
    order.sort_by(|&x, &y| evals[y].partial_cmp(&evals[x]).unwrap());
    let sorted_evals: Vec<f64> = order.iter().map(|&i| evals[i]).collect();
    let mut sorted_vecs = vec![0.0f64; k * k];
    for (newc, &oldc) in order.iter().enumerate() {
        for r in 0..k {
            sorted_vecs[r * k + newc] = v[r * k + oldc];
        }
    }
    (sorted_evals, sorted_vecs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_sym(k: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut a = vec![0.0; k * k];
        for i in 0..k {
            for j in 0..=i {
                let x = rng.normal();
                a[i * k + j] = x;
                a[j * k + i] = x;
            }
        }
        a
    }

    #[test]
    fn diagonal_matrix_trivial() {
        let a = vec![3.0, 0.0, 0.0, 0.0, -1.0, 0.0, 0.0, 0.0, 2.0];
        let (evals, _) = jacobi_eigh(&a, 3);
        assert!((evals[0] - 3.0).abs() < 1e-12);
        assert!((evals[1] - 2.0).abs() < 1e-12);
        assert!((evals[2] + 1.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_and_orthogonality() {
        let k = 12;
        let a = random_sym(k, 4);
        let (evals, vecs) = jacobi_eigh(&a, k);
        // V diag(e) Vᵀ == A
        for i in 0..k {
            for j in 0..k {
                let mut want = 0.0;
                for p in 0..k {
                    want += vecs[i * k + p] * evals[p] * vecs[j * k + p];
                }
                assert!((want - a[i * k + j]).abs() < 1e-9, "({i},{j})");
            }
        }
        // VᵀV == I
        for c1 in 0..k {
            for c2 in 0..k {
                let dot: f64 = (0..k).map(|r| vecs[r * k + c1] * vecs[r * k + c2]).sum();
                let want = if c1 == c2 { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn eigenvalues_sorted_descending() {
        let a = random_sym(8, 9);
        let (evals, _) = jacobi_eigh(&a, 8);
        assert!(evals.windows(2).all(|w| w[0] >= w[1] - 1e-12));
    }

    #[test]
    fn trace_preserved() {
        let k = 10;
        let a = random_sym(k, 5);
        let tr: f64 = (0..k).map(|i| a[i * k + i]).sum();
        let (evals, _) = jacobi_eigh(&a, k);
        assert!((evals.iter().sum::<f64>() - tr).abs() < 1e-9);
    }
}
