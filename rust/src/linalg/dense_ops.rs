//! Pure-Rust dense kernels: the fallback implementations of the block ops
//! that normally run in the AOT XLA artifacts, plus small helpers.
//! Shapes follow the artifact conventions (row-major, f32 storage, f64
//! accumulation where it matters for the paper's metrics).

use crate::sparse::Dense;

/// Gram matrix `G = YᵀY` (k×k, f64 accumulation) for a tall-skinny `Y`.
pub fn gram(y: &Dense) -> Vec<f64> {
    let (r, k) = (y.rows, y.cols);
    let mut g = vec![0.0f64; k * k];
    for i in 0..r {
        let row = y.row(i);
        for a in 0..k {
            let ya = row[a] as f64;
            if ya == 0.0 {
                continue;
            }
            let grow = &mut g[a * k..(a + 1) * k];
            for b in a..k {
                grow[b] += ya * row[b] as f64;
            }
        }
    }
    // mirror the upper triangle
    for a in 0..k {
        for b in 0..a {
            g[a * k + b] = g[b * k + a];
        }
    }
    g
}

/// `Q = Y · T` for tall-skinny `Y` (r×k) and small `T` (k×k row-major f64).
pub fn apply_factor(y: &Dense, t: &[f64]) -> Dense {
    let (r, k) = (y.rows, y.cols);
    assert_eq!(t.len(), k * k);
    let mut out = Dense::zeros(r, k);
    for i in 0..r {
        let src = y.row(i);
        let dst = out.row_mut(i);
        for a in 0..k {
            let v = src[a] as f64;
            if v == 0.0 {
                continue;
            }
            let trow = &t[a * k..(a + 1) * k];
            for b in 0..k {
                dst[b] += (v * trow[b]) as f32;
            }
        }
    }
    out
}

/// `P = Qᵀ · A` for row blocks `Q` (r×k), `A` (r×c); returns k×c.
pub fn proj(q: &Dense, a: &Dense) -> Dense {
    assert_eq!(q.rows, a.rows);
    let (r, k, c) = (q.rows, q.cols, a.cols);
    let mut out = Dense::zeros(k, c);
    for i in 0..r {
        let qrow = q.row(i);
        let arow = a.row(i);
        for x in 0..k {
            let qv = qrow[x];
            if qv == 0.0 {
                continue;
            }
            let dst = &mut out.data[x * c..(x + 1) * c];
            for (d, s) in dst.iter_mut().zip(arow.iter()) {
                *d += qv * s;
            }
        }
    }
    out
}

/// General small matmul `C = A·B` in f64 (for k×k factor algebra).
pub fn matmul_small(a: &[f64], ar: usize, ac: usize, b: &[f64], bc: usize) -> Vec<f64> {
    assert_eq!(a.len(), ar * ac);
    assert_eq!(b.len(), ac * bc);
    let mut c = vec![0.0; ar * bc];
    for i in 0..ar {
        for l in 0..ac {
            let v = a[i * ac + l];
            if v == 0.0 {
                continue;
            }
            let brow = &b[l * bc..(l + 1) * bc];
            let crow = &mut c[i * bc..(i + 1) * bc];
            for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += v * bv;
            }
        }
    }
    c
}

/// Max |off-diagonal| of a k×k symmetric matrix given as row-major f64 —
/// used to test orthonormality.
pub fn max_offdiag_dev_from_identity(g: &[f64], k: usize) -> f64 {
    let mut dev: f64 = 0.0;
    for i in 0..k {
        for j in 0..k {
            let target = if i == j { 1.0 } else { 0.0 };
            dev = dev.max((g[i * k + j] - target).abs());
        }
    }
    dev
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gram_matches_naive() {
        let mut rng = Rng::new(0);
        let y = Dense::randn(50, 4, &mut rng);
        let g = gram(&y);
        for a in 0..4 {
            for b in 0..4 {
                let want: f64 = (0..50).map(|i| y.get(i, a) as f64 * y.get(i, b) as f64).sum();
                assert!((g[a * 4 + b] - want).abs() < 1e-9, "({a},{b})");
            }
        }
    }

    #[test]
    fn apply_then_proj_consistent() {
        let mut rng = Rng::new(1);
        let y = Dense::randn(30, 3, &mut rng);
        let t = vec![1.0, 0.0, 0.0, 0.0, 2.0, 0.0, 0.0, 0.0, -1.0]; // diag(1,2,-1)
        let q = apply_factor(&y, &t);
        for i in 0..30 {
            assert!((q.get(i, 1) - 2.0 * y.get(i, 1)).abs() < 1e-5);
            assert!((q.get(i, 2) + y.get(i, 2)).abs() < 1e-5);
        }
        let a = Dense::randn(30, 7, &mut rng);
        let p = proj(&q, &a);
        let want: f64 = (0..30).map(|i| q.get(i, 0) as f64 * a.get(i, 0) as f64).sum();
        assert!((p.get(0, 0) as f64 - want).abs() < 1e-3);
    }

    #[test]
    fn matmul_small_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let id = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul_small(&a, 2, 2, &id, 2), a);
        let b = matmul_small(&a, 2, 2, &a, 2);
        assert_eq!(b, vec![7.0, 10.0, 15.0, 22.0]);
    }
}
