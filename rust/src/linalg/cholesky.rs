//! Small dense Cholesky factorization and triangular inversion (f64).
//!
//! Used for the Cholesky-QR orthonormalization step of the subspace
//! iteration: `G = YᵀY` (from the XLA gram artifact), `G = LLᵀ`,
//! `T = L⁻ᵀ`, `Q = Y·T` (XLA apply artifact). K ≤ 32 so cost is trivial.

use crate::error::{Error, Result};

/// Lower-triangular Cholesky factor of a k×k SPD matrix (row-major).
/// A small diagonal jitter is added on near-singular inputs, growing until
/// the factorization succeeds (subspace iterates can be rank-deficient in
/// early rounds).
pub fn cholesky(g: &[f64], k: usize) -> Result<Vec<f64>> {
    assert_eq!(g.len(), k * k);
    let scale = (0..k).map(|i| g[i * k + i]).fold(0.0f64, f64::max).max(1e-300);
    let mut jitter = 0.0;
    for attempt in 0..48 {
        match try_cholesky(g, k, jitter) {
            Ok(l) => return Ok(l),
            Err(_) => {
                jitter = if attempt == 0 { scale * 1e-14 } else { jitter * 10.0 };
            }
        }
    }
    Err(Error::Numeric("cholesky failed even with jitter".into()))
}

fn try_cholesky(g: &[f64], k: usize, jitter: f64) -> Result<Vec<f64>> {
    let mut l = vec![0.0f64; k * k];
    for i in 0..k {
        for j in 0..=i {
            let mut sum = g[i * k + j];
            if i == j {
                sum += jitter;
            }
            for p in 0..j {
                sum -= l[i * k + p] * l[j * k + p];
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(Error::Numeric(format!("non-PD at pivot {i}")));
                }
                l[i * k + i] = sum.sqrt();
            } else {
                l[i * k + j] = sum / l[j * k + j];
            }
        }
    }
    Ok(l)
}

/// Invert a lower-triangular k×k matrix (row-major) by forward substitution.
pub fn inv_lower(l: &[f64], k: usize) -> Result<Vec<f64>> {
    assert_eq!(l.len(), k * k);
    let mut inv = vec![0.0f64; k * k];
    for col in 0..k {
        // solve L x = e_col
        for i in col..k {
            let mut sum = if i == col { 1.0 } else { 0.0 };
            for p in col..i {
                sum -= l[i * k + p] * inv[p * k + col];
            }
            let d = l[i * k + i];
            if d == 0.0 {
                return Err(Error::Numeric(format!("singular diagonal at {i}")));
            }
            inv[i * k + col] = sum / d;
        }
    }
    Ok(inv)
}

/// The combined Cholesky-QR factor: given `G = YᵀY`, produce `T = L⁻ᵀ`
/// such that `Q = Y·T` has orthonormal columns.
pub struct CholeskyQr {
    /// k
    pub k: usize,
    /// `T = L⁻ᵀ` row-major (k×k, upper triangular).
    pub t: Vec<f64>,
    /// The Cholesky factor L (row-major lower triangular) — `R = Lᵀ` of QR.
    pub l: Vec<f64>,
}

impl CholeskyQr {
    /// Factor a Gram matrix.
    pub fn from_gram(g: &[f64], k: usize) -> Result<CholeskyQr> {
        let l = cholesky(g, k)?;
        let linv = inv_lower(&l, k)?;
        // T = (L⁻¹)ᵀ
        let mut t = vec![0.0f64; k * k];
        for i in 0..k {
            for j in 0..k {
                t[i * k + j] = linv[j * k + i];
            }
        }
        Ok(CholeskyQr { k, t, l })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense_ops::{gram, matmul_small, max_offdiag_dev_from_identity};
    use crate::sparse::Dense;
    use crate::util::rng::Rng;

    #[test]
    fn cholesky_reconstructs() {
        // G = M Mᵀ for random M
        let mut rng = Rng::new(2);
        let k = 6;
        let m = Dense::randn(k, k, &mut rng);
        let mut g = vec![0.0; k * k];
        for i in 0..k {
            for j in 0..k {
                g[i * k + j] = (0..k).map(|p| m.get(i, p) as f64 * m.get(j, p) as f64).sum();
            }
        }
        let l = cholesky(&g, k).unwrap();
        // L Lᵀ == G
        for i in 0..k {
            for j in 0..k {
                let want: f64 = (0..k).map(|p| l[i * k + p] * l[j * k + p]).sum();
                assert!((want - g[i * k + j]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn inv_lower_inverts() {
        let l = vec![2.0, 0.0, 0.0, 1.0, 3.0, 0.0, -1.0, 0.5, 4.0];
        let inv = inv_lower(&l, 3).unwrap();
        let prod = matmul_small(&l, 3, 3, &inv, 3);
        assert!(max_offdiag_dev_from_identity(&prod, 3) < 1e-12);
    }

    #[test]
    fn cholesky_qr_orthonormalizes() {
        let mut rng = Rng::new(3);
        let y = Dense::randn(500, 8, &mut rng);
        let g = gram(&y);
        let cqr = CholeskyQr::from_gram(&g, 8).unwrap();
        let q = crate::linalg::dense_ops::apply_factor(&y, &cqr.t);
        let gq = gram(&q);
        assert!(max_offdiag_dev_from_identity(&gq, 8) < 1e-4, "dev={}",
                max_offdiag_dev_from_identity(&gq, 8));
    }

    #[test]
    fn cholesky_handles_near_singular_with_jitter() {
        // rank-1 Gram matrix
        let v = [1.0, 2.0, 3.0];
        let mut g = vec![0.0; 9];
        for i in 0..3 {
            for j in 0..3 {
                g[i * 3 + j] = v[i] * v[j];
            }
        }
        let l = cholesky(&g, 3).unwrap();
        assert!(l[0] > 0.0);
    }
}
