//! Spectral norm (‖A‖₂) of sparse matrices by power iteration on `AᵀA`.

use crate::sparse::Csr;
use crate::util::rng::Rng;

/// Estimate `‖A‖₂ = σ₁(A)` with `iters` power-iteration rounds.
///
/// Each round computes `x ← Aᵀ(A·x)` and renormalizes; convergence is
/// geometric in `(σ₂/σ₁)²`, and the returned value is the Rayleigh
/// estimate `‖A·x‖₂` of the final unit vector — a lower bound that is
/// tight (≪1% error) within a few dozen rounds on the paper's matrices.
pub fn spectral_norm(a: &Csr, iters: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed ^ 0x9e37_79b9);
    let mut x: Vec<f32> = (0..a.n).map(|_| rng.normal() as f32).collect();
    let mut y = vec![0.0f32; a.m];
    let mut sigma = 0.0f64;
    for _ in 0..iters.max(1) {
        normalize(&mut x);
        a.spmv(&x, &mut y);
        sigma = norm(&y);
        // x ← Aᵀ y (unnormalized; normalized at loop head)
        spmv_t(a, &y, &mut x);
    }
    sigma
}

fn spmv_t(a: &Csr, y: &[f32], x: &mut [f32]) {
    x.iter_mut().for_each(|v| *v = 0.0);
    for i in 0..a.m {
        let yi = y[i];
        if yi == 0.0 {
            continue;
        }
        for (j, v) in a.row(i) {
            x[j as usize] += v * yi;
        }
    }
}

fn norm(v: &[f32]) -> f64 {
    v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
}

fn normalize(v: &mut [f32]) {
    let n = norm(v);
    if n > 0.0 {
        let inv = (1.0 / n) as f32;
        v.iter_mut().for_each(|x| *x *= inv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    #[test]
    fn diagonal_matrix_norm() {
        let mut coo = Coo::new(4, 4);
        for (i, v) in [1.0f32, -7.0, 3.0, 2.0].iter().enumerate() {
            coo.push(i as u32, i as u32, *v);
        }
        let a = coo.to_csr();
        let got = spectral_norm(&a, 100, 0);
        assert!((got - 7.0).abs() < 1e-3, "got={got}");
    }

    #[test]
    fn rank_one_norm_is_product_of_norms() {
        // A = u vᵀ with ‖u‖=5 (3-4-0...), ‖v‖=13 (5-12)
        let u = [3.0f32, 4.0];
        let v = [5.0f32, 12.0];
        let mut coo = Coo::new(2, 2);
        for i in 0..2 {
            for j in 0..2 {
                coo.push(i as u32, j as u32, u[i] * v[j]);
            }
        }
        let got = spectral_norm(&coo.to_csr(), 50, 1);
        assert!((got - 65.0).abs() / 65.0 < 1e-6, "got={got}");
    }

    #[test]
    fn agrees_with_subspace_svd() {
        use crate::linalg::svd::topk_svd;
        use crate::runtime::RustEngine;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        let mut coo = Coo::new(40, 120);
        for i in 0..40u32 {
            for _ in 0..20 {
                let j = rng.usize_below(120) as u32;
                coo.push(i, j, rng.normal() as f32);
            }
        }
        let a = coo.to_csr();
        let s1 = spectral_norm(&a, 200, 2);
        let svd = topk_svd(&a, 4, 12, 3, &RustEngine).unwrap();
        assert!((s1 - svd.sigma[0]).abs() / svd.sigma[0] < 5e-3,
                "power={s1} svd={}", svd.sigma[0]);
    }
}
