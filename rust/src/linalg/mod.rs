//! Dense linear algebra substrate.
//!
//! The small K×K factorizations ([`cholesky`], [`jacobi`]) run here in f64
//! (they cannot run in the AOT artifacts — xla_extension 0.5.1 rejects the
//! LAPACK typed-FFI custom-calls jax lowers them to); the FLOP-heavy
//! tall-skinny products run either in the XLA artifacts
//! ([`crate::runtime::XlaEngine`]) or the pure-Rust fallback
//! ([`dense_ops`]), both behind [`crate::runtime::DenseEngine`].

pub mod cholesky;
pub mod dense_ops;
pub mod jacobi;
pub mod power;
pub mod svd;

pub use cholesky::{cholesky, inv_lower, CholeskyQr};
pub use jacobi::jacobi_eigh;
pub use power::spectral_norm;
pub use svd::{topk_svd, SvdResult};
