//! Test support utilities, including the mini property-testing harness
//! ([`prop`]) that stands in for `proptest` (unavailable in the offline
//! registry — DESIGN.md §4).

pub mod prop;
