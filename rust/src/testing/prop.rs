//! A compact property-based testing harness.
//!
//! `proptest` cannot be fetched in this image, so this module provides the
//! pieces matsketch's invariant tests need: seeded case generation, a
//! configurable case count, and greedy input shrinking on failure (halving
//! numeric parameters while the property still fails), with the failing
//! seed printed for reproduction.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (case i uses `seed + i`).
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `property` over `cases` generated inputs. `generate` builds an
/// input from an [`Rng`]; `shrink` proposes smaller variants of a failing
/// input (return an empty vec to stop). Panics with the seed and the
/// smallest failing input's debug representation.
pub fn check<T: std::fmt::Debug + Clone>(
    cfg: PropConfig,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut shrink: impl FnMut(&T) -> Vec<T>,
    mut property: impl FnMut(&T) -> bool,
) {
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = generate(&mut rng);
        if property(&input) {
            continue;
        }
        // shrink greedily
        let mut smallest = input.clone();
        loop {
            let mut advanced = false;
            for cand in shrink(&smallest) {
                if !property(&cand) {
                    smallest = cand;
                    advanced = true;
                    break;
                }
            }
            if !advanced {
                break;
            }
        }
        panic!(
            "property failed (seed={seed}, case={case});\n  original: {input:?}\n  shrunk:   {smallest:?}"
        );
    }
}

/// Convenience shrinker for `Vec<T>`: propose halves and single-element
/// removals.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.len() > 1 {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
    }
    if v.len() > 1 && v.len() <= 16 {
        for i in 0..v.len() {
            let mut w = v.to_vec();
            w.remove(i);
            out.push(w);
        }
    }
    out
}

/// Convenience shrinker for positive integers: halvings toward 1.
pub fn shrink_u64(x: &u64) -> Vec<u64> {
    if *x <= 1 {
        vec![]
    } else {
        vec![x / 2, x - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        check(
            PropConfig { cases: 10, seed: 1 },
            |rng| rng.u64_below(100),
            |x| shrink_u64(x),
            |_| {
                ran += 1;
                true
            },
        );
        assert_eq!(ran, 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_shrunk_input() {
        check(
            PropConfig { cases: 50, seed: 2 },
            |rng| rng.u64_below(1000) + 10,
            shrink_u64,
            |&x| x < 10, // always false
        );
    }

    #[test]
    fn shrinkers_propose_smaller() {
        assert!(shrink_u64(&100).iter().all(|&x| x < 100));
        assert!(shrink_u64(&1).is_empty());
        let halves = shrink_vec(&[1, 2, 3, 4]);
        assert!(halves.iter().all(|h| h.len() < 4));
    }
}
