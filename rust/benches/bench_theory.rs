//! E6 — regenerates the ε₅ near-optimality table (Theorem 4.3 proxy) and
//! benches ComputeRowDistribution (Algorithm 1 lines 6–11).

#[path = "common/mod.rs"]
mod common;

use common::{bench, default_budget, section};
use matsketch::distributions::compute_row_distribution;
use matsketch::eval::run_theory;
use matsketch::util::rng::Rng;

fn main() {
    let budget = default_budget();
    let full = std::env::var("MATSKETCH_BENCH_FULL").is_ok();

    section("E6: eps5 near-optimality table");
    let pts = run_theory(std::path::Path::new("reports"), !full, 0).unwrap();
    println!(
        "{:<11} {:>12} {:>14} {:>12} {:>12} {:>10} {:>10}",
        "dataset", "s", "eps5(Bern)", "eps5(L1)", "eps5(RowL1)", "TV(L1)", "TV(RowL1)"
    );
    for p in &pts {
        println!(
            "{:<11} {:>12} {:>14.4e} {:>12.4e} {:>12.4e} {:>10.4} {:>10.4}",
            p.dataset, p.s, p.eps5_bernstein, p.eps5_l1, p.eps5_rowl1,
            p.tv_from_l1, p.tv_from_rowl1
        );
    }

    section("ComputeRowDistribution cost (binary search over zeta)");
    let mut rng = Rng::new(0);
    for m in [100usize, 10_000, 1_000_000] {
        let z: Vec<f64> = (0..m).map(|_| rng.f64_open() * 10.0).collect();
        bench(&format!("compute_row_distribution(m={m})"), budget, || {
            compute_row_distribution(&z, 1_000_000, 10 * m, 0.1).unwrap()
        })
        .report();
    }
}
