//! E1/E4 — regenerates the paper's §6 matrix-characteristics table and
//! the §4 sample-complexity comparison table, timing the metric
//! computations. Set `MATSKETCH_BENCH_FULL=1` for full-scale datasets.

#[path = "common/mod.rs"]
mod common;

use common::{bench, default_budget, section};
use matsketch::datasets::DatasetId;
use matsketch::eval::tables::{characteristics, write_tables};

fn main() {
    let budget = default_budget();
    let full = std::env::var("MATSKETCH_BENCH_FULL").is_ok();
    let seed = 0u64;

    section("E1/E4: matrix characteristics + sample-complexity tables");
    let mut rows = Vec::new();
    for id in DatasetId::all() {
        let coo = if full { id.generate(seed) } else { id.generate_small(seed) };
        let a = coo.to_csr();
        println!("{}: {}x{} nnz={}", id.name(), a.m, a.n, a.nnz());
        let mut row = None;
        bench(&format!("characteristics_{}", id.name()), budget, || {
            row = Some(characteristics(id.name(), &a, seed));
        })
        .report();
        rows.push(row.unwrap());
    }
    let dir = std::path::Path::new("reports");
    write_tables(dir, &rows).unwrap();

    println!("\n--- table_characteristics ---");
    println!("{}", std::fs::read_to_string(dir.join("table_characteristics.md")).unwrap());
    println!("--- table_sample_complexity ---");
    println!("{}", std::fs::read_to_string(dir.join("table_sample_complexity.md")).unwrap());
}
