//! E3 — regenerates the §1 compression experiment (bits/sample, codec vs
//! COO list sizes) and benches the codec throughput.

#[path = "common/mod.rs"]
mod common;

use common::{bench_items, default_budget, section};
use matsketch::distributions::DistributionKind;
use matsketch::eval::run_compression;
use matsketch::sketch::{decode_sketch, encode_sketch, sketch_offline, SketchPlan};
use matsketch::datasets::{synthetic_cf, SyntheticConfig};

fn main() {
    let budget = default_budget();
    let full = std::env::var("MATSKETCH_BENCH_FULL").is_ok();

    section("E3: bits-per-sample table");
    let pts = run_compression(std::path::Path::new("reports"), !full, 0).unwrap();
    println!("{:<11} {:>10} {:>12} {:>14} {:>12}", "dataset", "s", "bits/sample", "body bits/s", "vs zipped COO");
    for p in &pts {
        println!(
            "{:<11} {:>10} {:>12.2} {:>14.2} {:>12.3}",
            p.dataset, p.s, p.bits_per_sample, p.body_bits_per_sample, p.vs_compressed_coo
        );
    }

    section("codec throughput");
    let a = synthetic_cf(&SyntheticConfig { n: 20_000, ..Default::default() }).to_csr();
    let sk = sketch_offline(
        &a,
        &SketchPlan::new(DistributionKind::Bernstein, 200_000).with_seed(1),
    )
    .unwrap();
    let samples = 200_000f64;
    bench_items("encode_sketch(200k samples)", budget, samples, || {
        encode_sketch(&sk).unwrap().bytes.len()
    })
    .report();
    let enc = encode_sketch(&sk).unwrap();
    bench_items("decode_sketch(200k samples)", budget, samples, || {
        decode_sketch(&enc, "Bernstein").unwrap().nnz()
    })
    .report();
}
