//! E5/E7 — end-to-end streaming-engine throughput: nnz/s across sketcher
//! modes, worker counts, budgets, and distributions; plus backpressure
//! behaviour with tiny channels. Everything routes through the unified
//! `Sketcher` trait (`matsketch::engine`).

#[path = "common/mod.rs"]
mod common;

use common::{bench_items, default_budget, section};
use matsketch::datasets::{synthetic_cf, SyntheticConfig};
use matsketch::distributions::{DistributionKind, MatrixStats};
use matsketch::engine::{sketch_entry_stream, PipelineConfig, SketchMode};
use matsketch::sketch::SketchPlan;
use matsketch::stream::VecStream;

fn main() {
    let budget = default_budget();
    let a = synthetic_cf(&SyntheticConfig { m: 100, n: 40_000, ..Default::default() });
    let stats = MatrixStats::from_coo(&a);
    let nnz = a.nnz() as f64;
    println!("pipeline workload: {}x{}, nnz={}", a.m, a.n, a.nnz());

    section("engine: mode comparison (Bernstein, s=nnz/10)");
    for mode in SketchMode::all() {
        let cfg = PipelineConfig::default();
        let plan = SketchPlan::new(DistributionKind::Bernstein, (nnz as u64) / 10)
            .with_seed(7);
        bench_items(&format!("engine_mode={}", mode.name()), budget, nnz, || {
            let (sk, _m) =
                sketch_entry_stream(mode, VecStream::new(&a), &stats, &plan, &cfg).unwrap();
            sk.nnz()
        })
        .report();
    }

    section("pipeline: worker scaling (Bernstein, s=nnz/10)");
    for workers in [1usize, 2, 4, 8] {
        let cfg = PipelineConfig { workers, ..Default::default() };
        let plan = SketchPlan::new(DistributionKind::Bernstein, (nnz as u64) / 10)
            .with_seed(1);
        bench_items(&format!("pipeline_workers={workers}"), budget, nnz, || {
            let (sk, _m) = sketch_entry_stream(
                SketchMode::Sharded,
                VecStream::new(&a),
                &stats,
                &plan,
                &cfg,
            )
            .unwrap();
            sk.nnz()
        })
        .report();
    }

    section("pipeline: budget scaling (4 workers)");
    for frac in [100u64, 10, 2] {
        let s = (nnz as u64) / frac;
        let cfg = PipelineConfig { workers: 4, ..Default::default() };
        let plan = SketchPlan::new(DistributionKind::Bernstein, s).with_seed(2);
        bench_items(&format!("pipeline_s=nnz/{frac}"), budget, nnz, || {
            sketch_entry_stream(SketchMode::Sharded, VecStream::new(&a), &stats, &plan, &cfg)
                .unwrap()
                .0
                .nnz()
        })
        .report();
    }

    section("pipeline: distribution comparison (4 workers, s=nnz/10)");
    for kind in [
        DistributionKind::Bernstein,
        DistributionKind::RowL1,
        DistributionKind::L1,
        DistributionKind::L2,
    ] {
        let cfg = PipelineConfig { workers: 4, ..Default::default() };
        let plan = SketchPlan::new(kind, (nnz as u64) / 10).with_seed(3);
        bench_items(&format!("pipeline_{}", kind.name()), budget, nnz, || {
            sketch_entry_stream(SketchMode::Sharded, VecStream::new(&a), &stats, &plan, &cfg)
                .unwrap()
                .0
                .nnz()
        })
        .report();
    }

    // ROADMAP "leader-path micro-perf": would a per-entry `ingest_one`
    // trait method beat the buffered stream driver on the sharded path?
    // Measured exactly — `ingest(&[e])` per entry (what an ingest_one
    // default method would do) vs the default batched driver. The
    // buffered form stays unless per-entry wins; numbers are recorded
    // in ROADMAP.md.
    section("leader ingest granularity: buffered driver vs per-entry ingest");
    {
        use matsketch::engine::build_sketcher;
        use matsketch::stream::EntryStream;
        for workers in [1usize, 4] {
            let cfg = PipelineConfig { workers, ..Default::default() };
            let plan = SketchPlan::new(DistributionKind::Bernstein, (nnz as u64) / 10)
                .with_seed(5);
            bench_items(
                &format!("leader_buffered_batch{}_w{workers}", cfg.batch),
                budget,
                nnz,
                || {
                    sketch_entry_stream(
                        SketchMode::Sharded,
                        VecStream::new(&a),
                        &stats,
                        &plan,
                        &cfg,
                    )
                    .unwrap()
                    .0
                    .nnz()
                },
            )
            .report();
            bench_items(&format!("leader_ingest_one_w{workers}"), budget, nnz, || {
                let mut sketcher =
                    build_sketcher(SketchMode::Sharded, &stats, &plan, &cfg).unwrap();
                let mut stream = VecStream::new(&a);
                while let Some(e) = stream.next_entry().unwrap() {
                    sketcher.ingest(std::slice::from_ref(&e)).unwrap();
                }
                sketcher.finalize().unwrap().0.nnz()
            })
            .report();
        }
    }

    section("pipeline: backpressure (tiny channels, bounded spill)");
    let cfg = PipelineConfig {
        workers: 4,
        channel_cap: 1,
        batch: 64,
        spill_cap: 2,
        ..Default::default()
    };
    let plan = SketchPlan::new(DistributionKind::Bernstein, (nnz as u64) / 10).with_seed(4);
    bench_items("pipeline_channel_cap=1_batch=64_spill=2", budget, nnz, || {
        sketch_entry_stream(SketchMode::Sharded, VecStream::new(&a), &stats, &plan, &cfg)
            .unwrap()
            .0
            .nnz()
    })
    .report();
}
