//! E5/E7 — end-to-end streaming-pipeline throughput: nnz/s across worker
//! counts, budgets, and distributions; plus backpressure behaviour with
//! tiny channels.

#[path = "common/mod.rs"]
mod common;

use common::{bench_items, default_budget, section};
use matsketch::coordinator::{sketch_stream, PipelineConfig};
use matsketch::datasets::{synthetic_cf, SyntheticConfig};
use matsketch::distributions::{DistributionKind, MatrixStats};
use matsketch::sketch::SketchPlan;
use matsketch::stream::VecStream;

fn main() {
    let budget = default_budget();
    let a = synthetic_cf(&SyntheticConfig { m: 100, n: 40_000, ..Default::default() });
    let stats = MatrixStats::from_coo(&a);
    let nnz = a.nnz() as f64;
    println!("pipeline workload: {}x{}, nnz={}", a.m, a.n, a.nnz());

    section("pipeline: worker scaling (Bernstein, s=nnz/10)");
    for workers in [1usize, 2, 4, 8] {
        let cfg = PipelineConfig { workers, ..Default::default() };
        let plan = SketchPlan::new(DistributionKind::Bernstein, (nnz as u64) / 10)
            .with_seed(1);
        bench_items(&format!("pipeline_workers={workers}"), budget, nnz, || {
            let (sk, _m) =
                sketch_stream(VecStream::new(&a), &stats, &plan, &cfg).unwrap();
            sk.nnz()
        })
        .report();
    }

    section("pipeline: budget scaling (4 workers)");
    for frac in [100u64, 10, 2] {
        let s = (nnz as u64) / frac;
        let cfg = PipelineConfig { workers: 4, ..Default::default() };
        let plan = SketchPlan::new(DistributionKind::Bernstein, s).with_seed(2);
        bench_items(&format!("pipeline_s=nnz/{frac}"), budget, nnz, || {
            sketch_stream(VecStream::new(&a), &stats, &plan, &cfg).unwrap().0.nnz()
        })
        .report();
    }

    section("pipeline: distribution comparison (4 workers, s=nnz/10)");
    for kind in [
        DistributionKind::Bernstein,
        DistributionKind::RowL1,
        DistributionKind::L1,
        DistributionKind::L2,
    ] {
        let cfg = PipelineConfig { workers: 4, ..Default::default() };
        let plan = SketchPlan::new(kind, (nnz as u64) / 10).with_seed(3);
        bench_items(&format!("pipeline_{}", kind.name()), budget, nnz, || {
            sketch_stream(VecStream::new(&a), &stats, &plan, &cfg).unwrap().0.nnz()
        })
        .report();
    }

    section("pipeline: backpressure (tiny channels)");
    let cfg = PipelineConfig { workers: 4, channel_cap: 1, batch: 64 };
    let plan = SketchPlan::new(DistributionKind::Bernstein, (nnz as u64) / 10).with_seed(4);
    bench_items("pipeline_channel_cap=1_batch=64", budget, nnz, || {
        sketch_stream(VecStream::new(&a), &stats, &plan, &cfg).unwrap().0.nnz()
    })
    .report();
}
