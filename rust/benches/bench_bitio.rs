//! E12 — decode-path throughput: the word-level γ codec vs the scalar
//! per-bit baseline, and row-parallel compressed matvec worker scaling
//! on the tall-matrix shape. Since every serving op streams off the
//! Elias-γ payload, γ-decode throughput *is* serving throughput.
//!
//! Besides the usual bench lines, this binary writes the perf-trajectory
//! artifacts CI asserts on: `<out>/decode_throughput.{csv,md}` and
//! `<out>/BENCH_decode.json` (γ-decode MB/s for both codecs, the
//! speedup, matvec GFLOP-equivalents, and per-worker-count scaling).
//! `--out DIR` overrides the default `reports` directory.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use common::{bench_items, default_budget, section, BenchResult};
use matsketch::api::{QueryRequest, QueryResponse};
use matsketch::datasets::{synthetic_cf, SyntheticConfig};
use matsketch::distributions::DistributionKind;
use matsketch::eval::report::{fixed, Table};
use matsketch::serve::{QueryServer, ServableSketch};
use matsketch::sketch::bitio::scalar::{ScalarBitReader, ScalarBitWriter};
use matsketch::sketch::bitio::{BitReader, BitWriter};
use matsketch::sketch::{encode_sketch, sketch_offline, SketchPlan};
use matsketch::util::json::{num, obj, Json};
use matsketch::util::rng::Rng;

/// A γ-value stream shaped like a sketch payload body: mostly small
/// column deltas and multiplicities, a tail of large row jumps.
fn payload_like_values(count: usize, seed: u64) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| match rng.u64_below(16) {
            0..=9 => 1 + rng.u64_below(8),            // small deltas dominate
            10..=13 => 1 + rng.u64_below(1 << 10),    // medium gaps
            14 => 1 + rng.u64_below(1 << 24),         // large gaps
            _ => 1 + rng.u64_below(u64::MAX >> 16),   // rare huge jumps
        })
        .collect()
}

fn main() {
    let out = out_dir();
    let budget = default_budget();
    let mut table = Table::new(
        "decode_throughput",
        &["section", "name", "median_us", "throughput", "unit", "speedup"],
    );
    let mut json: Vec<(&str, Json)> = Vec::new();

    // --- γ codec: word-level vs per-bit scalar baseline ---
    let vals = payload_like_values(2_000_000, 0xB17);
    let mut w = BitWriter::new();
    for &v in &vals {
        w.put_gamma(v);
    }
    let payload = w.finish();
    let mb = payload.len() as f64 / 1e6;
    println!(
        "γ stream: {} values, {:.2} MB encoded ({:.2} bits/value)",
        vals.len(),
        mb,
        payload.len() as f64 * 8.0 / vals.len() as f64
    );

    section("γ encode: word-level writer vs per-bit baseline");
    let enc_scalar = bench_items("gamma_encode_scalar", budget, vals.len() as f64, || {
        let mut w = ScalarBitWriter::new();
        for &v in &vals {
            w.put_gamma(v);
        }
        w.finish().len()
    });
    enc_scalar.report();
    let enc_word = bench_items("gamma_encode_word", budget, vals.len() as f64, || {
        let mut w = BitWriter::new();
        for &v in &vals {
            w.put_gamma(v);
        }
        w.finish().len()
    });
    enc_word.report();

    // the put_bits satellite micro-bench: byte-aligned fixed-width runs
    // (the store-container header path) — the old writer looped put_bit
    section("aligned put_bits: word-level writer vs per-bit baseline");
    let words: Vec<u64> = {
        let mut rng = Rng::new(0xA11);
        (0..500_000).map(|_| rng.next_u64()).collect()
    };
    let putbits_scalar = bench_items("put_bits64_scalar", budget, words.len() as f64, || {
        let mut w = ScalarBitWriter::new();
        for &v in &words {
            w.put_bits(v, 64);
        }
        w.finish().len()
    });
    putbits_scalar.report();
    let putbits_word = bench_items("put_bits64_word", budget, words.len() as f64, || {
        let mut w = BitWriter::new();
        for &v in &words {
            w.put_bits(v, 64);
        }
        w.finish().len()
    });
    putbits_word.report();

    section("γ decode: word-level reader vs per-bit baseline");
    let dec_scalar = bench_items("gamma_decode_scalar", budget, vals.len() as f64, || {
        let mut r = ScalarBitReader::new(&payload);
        let mut sum = 0u64;
        while let Some(v) = r.get_gamma() {
            sum = sum.wrapping_add(v);
        }
        sum
    });
    dec_scalar.report();
    let dec_word = bench_items("gamma_decode_word", budget, vals.len() as f64, || {
        let mut r = BitReader::new(&payload);
        let mut sum = 0u64;
        while let Some(v) = r.get_gamma() {
            sum = sum.wrapping_add(v);
        }
        sum
    });
    dec_word.report();

    let scalar_mbs = mb / dec_scalar.median;
    let word_mbs = mb / dec_word.median;
    let decode_speedup = word_mbs / scalar_mbs;
    println!(
        "γ decode: scalar {scalar_mbs:.1} MB/s, word {word_mbs:.1} MB/s \
         ({decode_speedup:.2}x, target ≥3x)"
    );

    push_codec_row(&mut table, "gamma_encode", "scalar", &enc_scalar, mb, 1.0);
    push_codec_row(
        &mut table,
        "gamma_encode",
        "word",
        &enc_word,
        mb,
        enc_scalar.median / enc_word.median,
    );
    push_codec_row(&mut table, "put_bits64", "scalar", &putbits_scalar, 4.0, 1.0);
    push_codec_row(
        &mut table,
        "put_bits64",
        "word",
        &putbits_word,
        4.0,
        putbits_scalar.median / putbits_word.median,
    );
    push_codec_row(&mut table, "gamma_decode", "scalar", &dec_scalar, mb, 1.0);
    push_codec_row(&mut table, "gamma_decode", "word", &dec_word, mb, decode_speedup);
    json.push(("gamma_decode_scalar_mb_s", num(scalar_mbs)));
    json.push(("gamma_decode_word_mb_s", num(word_mbs)));
    json.push(("gamma_decode_speedup", num(decode_speedup)));
    json.push(("gamma_encode_speedup", num(enc_scalar.median / enc_word.median)));
    json.push(("put_bits64_speedup", num(putbits_scalar.median / putbits_word.median)));

    // --- row-parallel matvec scaling on the tall-matrix shape ---
    section("row-parallel matvec: 20000-row sketch, worker scaling");
    let tall = synthetic_cf(&SyntheticConfig { m: 20_000, n: 100, ..Default::default() })
        .to_csr();
    let s_tall = (tall.nnz() as u64) / 10;
    let plan = SketchPlan::new(DistributionKind::Bernstein, s_tall).with_seed(3);
    let sk = sketch_offline(&tall, &plan).unwrap();
    let enc = encode_sketch(&sk).unwrap();
    let nnz = sk.nnz() as f64;
    let servable = Arc::new(ServableSketch::new(enc, plan.kind.name()).unwrap());
    println!(
        "tall sketch: {}x{}, {} stored entries, {} occupied rows",
        tall.m,
        tall.n,
        sk.nnz(),
        servable.row_index().len()
    );
    let mut rng = Rng::new(0x7A11);
    let x: Vec<f64> = (0..tall.n).map(|_| rng.normal()).collect();

    let queries_per_iter = 8usize;
    let mut base_median = 0.0f64;
    let mut scaling: Vec<(usize, f64, f64)> = Vec::new(); // (workers, qps, speedup)
    for workers in [1usize, 2, 4] {
        // split threshold 1 so the single-query fork/reduce path is what
        // w>1 measures; submissions are sequential, so the speedup is
        // pure row-parallel decode scaling, not request concurrency
        let server = QueryServer::start_with(Arc::clone(&servable), workers, 1);
        let r = bench_items(
            &format!("matvec_split_workers={workers}"),
            budget,
            nnz * queries_per_iter as f64,
            || {
                for _ in 0..queries_per_iter {
                    let QueryResponse::Vector(y) =
                        server.submit(QueryRequest::Matvec(x.clone())).wait().unwrap()
                    else {
                        unreachable!("matvec answers are vectors");
                    };
                    std::hint::black_box(y);
                }
            },
        );
        r.report();
        server.shutdown();
        if workers == 1 {
            base_median = r.median;
        }
        let qps = queries_per_iter as f64 / r.median;
        let gflops = 2.0 * nnz * queries_per_iter as f64 / r.median / 1e9;
        let speedup = base_median / r.median;
        table.push(vec![
            "matvec".into(),
            format!("workers={workers}"),
            fixed(r.median * 1e6 / queries_per_iter as f64, 1),
            fixed(qps, 1),
            "queries/s".into(),
            fixed(speedup, 2),
        ]);
        json.push((
            match workers {
                1 => "matvec_workers_1_qps",
                2 => "matvec_workers_2_qps",
                _ => "matvec_workers_4_qps",
            },
            num(qps),
        ));
        scaling.push((workers, qps, speedup));
        println!(
            "  workers={workers}: {qps:.1} queries/s, {gflops:.3} GFLOP-equiv, \
             {speedup:.2}x vs 1 worker"
        );
    }
    let gflops_best = scaling
        .iter()
        .map(|&(_, qps, _)| 2.0 * nnz * qps / 1e9)
        .fold(0.0f64, f64::max);
    json.push(("matvec_gflop_equiv_best", num(gflops_best)));
    json.push((
        "matvec_speedup_4_workers",
        num(scaling.last().map(|&(_, _, s)| s).unwrap_or(0.0)),
    ));

    // --- perf-trajectory artifacts ---
    table.write(&out).expect("write decode_throughput tables");
    let json_path = out.join("BENCH_decode.json");
    std::fs::write(&json_path, obj(json).to_string()).expect("write BENCH_decode.json");
    println!(
        "\nwrote {}/decode_throughput.{{csv,md}} and {}",
        out.display(),
        json_path.display()
    );
}

/// `--out DIR` (default `reports`), tolerated anywhere in the arg list.
fn out_dir() -> std::path::PathBuf {
    let mut args = std::env::args();
    while let Some(arg) = args.next() {
        if arg == "--out" {
            if let Some(dir) = args.next() {
                return dir.into();
            }
        }
    }
    "reports".into()
}

/// One codec row: throughput in MB/s of the shared payload size.
fn push_codec_row(
    table: &mut Table,
    section: &str,
    name: &str,
    r: &BenchResult,
    mb: f64,
    speedup: f64,
) {
    table.push(vec![
        section.into(),
        name.into(),
        fixed(r.median * 1e6, 1),
        fixed(mb / r.median, 1),
        "MB/s".into(),
        fixed(speedup, 2),
    ]);
}
