//! Shared bench harness (criterion is unavailable offline — DESIGN.md §4).
//!
//! Provides warmup + repeated timing with median/MAD reporting and a
//! machine-readable JSON line per benchmark, so `cargo bench` output can
//! be diffed across the §Perf iterations.
#![allow(dead_code)] // not every bench binary uses every helper

use std::time::{Duration, Instant};

use matsketch::util::stats::{mad, quantile};

/// One benchmark measurement.
pub struct BenchResult {
    /// Name.
    pub name: String,
    /// Median seconds per iteration.
    pub median: f64,
    /// Median absolute deviation.
    pub mad: f64,
    /// Iterations measured.
    pub iters: usize,
    /// Optional throughput denominator (items per iteration).
    pub items: Option<f64>,
}

impl BenchResult {
    /// Render the human + JSON lines.
    pub fn report(&self) {
        let thr = self
            .items
            .map(|it| format!("  {:>10.2} Mitem/s", it / self.median / 1e6))
            .unwrap_or_default();
        println!(
            "bench {:<44} {:>12} ±{:>10}{}",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mad),
            thr
        );
        println!(
            "{{\"bench\":\"{}\",\"median_s\":{:.9},\"mad_s\":{:.9},\"iters\":{}{}}}",
            self.name,
            self.median,
            self.mad,
            self.iters,
            self.items
                .map(|i| format!(",\"items\":{i}"))
                .unwrap_or_default()
        );
    }
}

fn fmt_dur(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark `f`, auto-scaling iteration count to ~`budget` wall time.
pub fn bench<T>(name: &str, budget: Duration, mut f: impl FnMut() -> T) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let target_iters = ((budget.as_secs_f64() / once).ceil() as usize).clamp(3, 1000);

    let mut times = Vec::with_capacity(target_iters);
    for _ in 0..target_iters {
        let t = Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        median: quantile(&times, 0.5),
        mad: mad(&times),
        iters: target_iters,
        items: None,
    }
}

/// Benchmark with a throughput denominator.
pub fn bench_items<T>(
    name: &str,
    budget: Duration,
    items: f64,
    f: impl FnMut() -> T,
) -> BenchResult {
    let mut r = bench(name, budget, f);
    r.items = Some(items);
    r
}

/// Standard per-bench budget (overridable via `MATSKETCH_BENCH_BUDGET_MS`).
pub fn default_budget() -> Duration {
    std::env::var("MATSKETCH_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_millis)
        .unwrap_or(Duration::from_millis(1_500))
}

/// Section header for grouped output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
