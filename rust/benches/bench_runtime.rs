//! §Perf L1/L2 — AOT-artifact block-op latency through PJRT vs the
//! pure-Rust fallback, across the shapes the evaluation pipeline feeds.

#[path = "common/mod.rs"]
mod common;

use common::{bench, bench_items, default_budget, section};
use matsketch::runtime::{DenseEngine, RustEngine, XlaEngine};
use matsketch::sparse::{Coo, Dense};
use matsketch::util::rng::Rng;

fn main() {
    let budget = default_budget();
    let xla = XlaEngine::from_dir(std::path::Path::new("artifacts")).ok();
    if xla.is_none() {
        println!("NOTE: artifacts/ missing — run `make artifacts`; benching Rust engine only");
    }
    let mut rng = Rng::new(0);

    let engines: Vec<(&str, &dyn DenseEngine)> = {
        let mut v: Vec<(&str, &dyn DenseEngine)> = vec![("rust", &RustEngine)];
        if let Some(x) = xla.as_ref() {
            v.push(("xla", x));
        }
        v
    };

    for (rows, k) in [(2048usize, 32usize), (16_384, 32)] {
        section(&format!("gram/apply: Y = {rows}x{k}"));
        let y = Dense::randn(rows, k, &mut rng);
        let flops = (rows * k * k) as f64;
        for (name, e) in &engines {
            bench_items(&format!("gram_{name}_r{rows}"), budget, flops, || {
                e.gram(&y).unwrap()
            })
            .report();
        }
        let t: Vec<f64> = (0..k * k).map(|i| if i % (k + 1) == 0 { 1.0 } else { 0.01 }).collect();
        for (name, e) in &engines {
            bench_items(&format!("apply_{name}_r{rows}"), budget, flops, || {
                e.apply(&y, &t).unwrap()
            })
            .report();
        }
    }

    section("proj: Q=4096x32, A=4096x2048 (column-windowed)");
    let q = Dense::randn(4096, 32, &mut rng);
    let a = Dense::randn(4096, 2048, &mut rng);
    let flops = (4096usize * 32 * 2048) as f64;
    for (name, e) in &engines {
        bench_items(&format!("proj_{name}"), budget, flops, || {
            e.proj(&q, &a).unwrap()
        })
        .report();
    }

    section("power_iter: G=32x32, 96 iterations");
    let m32 = Dense::randn(32, 32, &mut rng);
    let g = RustEngine.gram(&m32).unwrap();
    for (name, e) in &engines {
        bench(&format!("power_iter_{name}"), budget, || e.power_iter(&g, 32).unwrap())
            .report();
    }

    section("SpMM (rust hot path): A sparse 2000x20000 (nnz=200k) x V 20000x32");
    let mut coo = Coo::new(2_000, 20_000);
    for i in 0..2_000u32 {
        for _ in 0..100 {
            coo.push(i, rng.usize_below(20_000) as u32, rng.normal() as f32);
        }
    }
    coo.normalize();
    let sp = coo.to_csr();
    let v = Dense::randn(20_000, 32, &mut rng);
    let u = Dense::randn(2_000, 32, &mut rng);
    let spmm_flops = (sp.nnz() * 32 * 2) as f64;
    bench_items("spmm_A*V", budget, spmm_flops, || sp.spmm(&v)).report();
    bench_items("spmm_At*U", budget, spmm_flops, || sp.spmm_t(&u)).report();
}
