//! E10 — serving-path throughput: matvec queries/sec executed directly on
//! the Elias-γ compressed sketch vs the decode-then-CSR fallback, across
//! the Figure-1 distributions; plus `QueryServer` concurrent-reader
//! scaling.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use common::{bench_items, default_budget, section};
use matsketch::datasets::{synthetic_cf, SyntheticConfig};
use matsketch::distributions::DistributionKind;
use matsketch::serve::{self, Query, QueryServer, ServableSketch};
use matsketch::sketch::{
    decode_sketch, encode_sketch, row_group_index, sketch_offline, PayloadHeader, SketchPlan,
};
use matsketch::util::rng::Rng;

fn main() {
    let budget = default_budget();
    let a = synthetic_cf(&SyntheticConfig { m: 100, n: 20_000, ..Default::default() })
        .to_csr();
    let s = (a.nnz() as u64) / 10;
    println!("serve workload: {}x{}, nnz={}, s={s}", a.m, a.n, a.nnz());

    let mut rng = Rng::new(0xBE7C);
    let x: Vec<f64> = (0..a.n).map(|_| rng.normal()).collect();
    let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();

    section("matvec: compressed path vs decode-then-CSR (per query)");
    for kind in DistributionKind::figure1_set() {
        let sk = sketch_offline(&a, &SketchPlan::new(kind, s).with_seed(3)).unwrap();
        let enc = encode_sketch(&sk).unwrap();
        let name = kind.name();
        let nnz = sk.nnz() as f64;

        bench_items(&format!("matvec_compressed[{name}]"), budget, nnz, || {
            serve::matvec(&enc, &x).unwrap()
        })
        .report();

        bench_items(&format!("matvec_decode_then_csr[{name}]"), budget, nnz, || {
            // the fallback pays a full decode + CSR build on every query
            let dec = decode_sketch(&enc, &name).unwrap();
            let csr = dec.to_csr();
            let mut y = vec![0.0f32; csr.m];
            csr.spmv(&xf, &mut y);
            y
        })
        .report();

        // steady-state fallback: CSR materialized once, spmv per query
        let csr = decode_sketch(&enc, &name).unwrap().to_csr();
        bench_items(&format!("matvec_csr_hot[{name}]"), budget, nnz, || {
            let mut y = vec![0.0f32; csr.m];
            csr.spmv(&xf, &mut y);
            y
        })
        .report();
    }

    section("top-k: compressed path (Bernstein)");
    let sk = sketch_offline(&a, &SketchPlan::new(DistributionKind::Bernstein, s).with_seed(3))
        .unwrap();
    let enc = encode_sketch(&sk).unwrap();
    for k in [10usize, 100] {
        bench_items(&format!("top_{k}_compressed"), budget, sk.nnz() as f64, || {
            serve::top_k(&enc, k).unwrap()
        })
        .report();
    }

    // ROADMAP flagged the per-query header re-read (the m-entry
    // row-scale table) as dominating row/top-k latency on tall matrices;
    // ServableSketch now parses it once. Quantify the win on a tall
    // sketch: cold = one-shot ops (header parsed per query), cached =
    // the *_h forms, indexed = the store's per-row seek index.
    section("header cache + row index: tall matrix (20000 x 100) row/top-k");
    {
        let tall = synthetic_cf(&SyntheticConfig { m: 20_000, n: 100, ..Default::default() })
            .to_csr();
        let s_tall = (tall.nnz() as u64) / 10;
        let plan = SketchPlan::new(DistributionKind::Bernstein, s_tall).with_seed(3);
        let sk = sketch_offline(&tall, &plan).unwrap();
        let enc = encode_sketch(&sk).unwrap();
        let header = PayloadHeader::parse(&enc).unwrap();
        let index = row_group_index(&enc).unwrap();
        let mut rng = Rng::new(0x7A11);
        let rows: Vec<u32> = (0..64).map(|_| rng.usize_below(tall.m) as u32).collect();
        let per = rows.len() as f64;

        bench_items("row_slice_cold_header", budget, per, || {
            rows.iter().map(|&i| serve::row_slice(&enc, i).unwrap().len()).sum::<usize>()
        })
        .report();
        bench_items("row_slice_cached_header", budget, per, || {
            rows.iter()
                .map(|&i| serve::row_slice_h(&enc, &header, i).unwrap().len())
                .sum::<usize>()
        })
        .report();
        bench_items("row_slice_indexed", budget, per, || {
            rows.iter()
                .map(|&i| serve::row_slice_indexed(&enc, &header, &index, i).unwrap().len())
                .sum::<usize>()
        })
        .report();

        bench_items("top_10_cold_header", budget, 1.0, || {
            serve::top_k(&enc, 10).unwrap()
        })
        .report();
        bench_items("top_10_cached_header", budget, 1.0, || {
            serve::top_k_h(&enc, &header, 10).unwrap()
        })
        .report();
    }

    section("QueryServer: concurrent matvec readers (Bernstein)");
    let servable =
        Arc::new(ServableSketch::new(enc, DistributionKind::Bernstein.name()).unwrap());
    for readers in [1usize, 2, 4, 8] {
        let queries = 32usize;
        bench_items(
            &format!("server_readers={readers}"),
            budget,
            queries as f64,
            || {
                let server = QueryServer::start(Arc::clone(&servable), readers);
                let pending =
                    server.submit_batch(vec![Query::Matvec(x.clone()); queries]);
                for p in pending {
                    p.wait().unwrap();
                }
                server.shutdown().total()
            },
        )
        .report();
    }
}
