//! E10 — serving-path throughput: matvec queries/sec executed directly on
//! the Elias-γ compressed sketch vs the decode-then-CSR fallback, across
//! the Figure-1 distributions; the batched single-pass SpMM vs k
//! independent matvecs; plus `QueryServer` concurrent-reader scaling.
//!
//! Also the instrumentation-overhead guards: the same served-matvec
//! workload with the `obs` registry recording vs disabled, and with
//! request tracing at the default 1-in-64 sampling vs disabled, written
//! to `<out>/BENCH_obs.json` (`--out DIR` overrides the default
//! `reports`) so CI can hold both to their <2% overhead claims.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use common::{bench_items, default_budget, section};
use matsketch::api::{QueryRequest, QueryResponse};
use matsketch::datasets::{synthetic_cf, SyntheticConfig};
use matsketch::distributions::DistributionKind;
use matsketch::obs::trace;
use matsketch::serve::{self, QueryServer, ServableSketch};
use matsketch::sketch::{decode_sketch, encode_sketch, sketch_offline, SketchPlan};
use matsketch::util::json::{num, obj, Json};
use matsketch::util::rng::Rng;

fn main() {
    let budget = default_budget();
    let a = synthetic_cf(&SyntheticConfig { m: 100, n: 20_000, ..Default::default() })
        .to_csr();
    let s = (a.nnz() as u64) / 10;
    println!("serve workload: {}x{}, nnz={}, s={s}", a.m, a.n, a.nnz());

    let mut rng = Rng::new(0xBE7C);
    let x: Vec<f64> = (0..a.n).map(|_| rng.normal()).collect();
    let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();

    section("matvec: compressed path vs decode-then-CSR (per query)");
    for kind in DistributionKind::figure1_set() {
        let sk = sketch_offline(&a, &SketchPlan::new(kind, s).with_seed(3)).unwrap();
        let enc = encode_sketch(&sk).unwrap();
        let name = kind.name();
        let nnz = sk.nnz() as f64;

        bench_items(&format!("matvec_compressed[{name}]"), budget, nnz, || {
            serve::matvec(&enc, &x).unwrap()
        })
        .report();

        bench_items(&format!("matvec_decode_then_csr[{name}]"), budget, nnz, || {
            // the fallback pays a full decode + CSR build on every query
            let dec = decode_sketch(&enc, &name).unwrap();
            let csr = dec.to_csr();
            let mut y = vec![0.0f32; csr.m];
            csr.spmv(&xf, &mut y);
            y
        })
        .report();

        // steady-state fallback: CSR materialized once, spmv per query
        let csr = decode_sketch(&enc, &name).unwrap().to_csr();
        bench_items(&format!("matvec_csr_hot[{name}]"), budget, nnz, || {
            let mut y = vec![0.0f32; csr.m];
            csr.spmv(&xf, &mut y);
            y
        })
        .report();
    }

    section("top-k: compressed path (Bernstein)");
    let sk = sketch_offline(&a, &SketchPlan::new(DistributionKind::Bernstein, s).with_seed(3))
        .unwrap();
    let enc = encode_sketch(&sk).unwrap();
    for k in [10usize, 100] {
        bench_items(&format!("top_{k}_compressed"), budget, sk.nnz() as f64, || {
            serve::top_k(&enc, k).unwrap()
        })
        .report();
    }

    // the serving_batch.* story: one payload pass for k right-hand sides
    // vs k independent passes. Throughput is reported per matvec, so the
    // batched lines should climb with k while the independent ones stay
    // flat — that gap is the amortized Elias-γ decode.
    section("batched matvec: one-pass SpMM vs k independent matvecs (Bernstein)");
    {
        let mut rng = Rng::new(0xBA7C);
        for k in [1usize, 4, 16] {
            let xs: Vec<Vec<f64>> = (0..k)
                .map(|_| (0..a.n).map(|_| rng.normal()).collect())
                .collect();
            let per = (sk.nnz() as f64) * k as f64;
            bench_items(&format!("matvec_batch_one_pass[k={k}]"), budget, per, || {
                serve::matvec_batch(&enc, &xs).unwrap()
            })
            .report();
            bench_items(&format!("matvec_independent[k={k}]"), budget, per, || {
                xs.iter().map(|xi| serve::matvec(&enc, xi).unwrap()).collect::<Vec<_>>()
            })
            .report();
        }
    }

    // ROADMAP flagged the per-query header re-read (the m-entry
    // row-scale table) as dominating row/top-k latency on tall matrices;
    // plan selection now lives behind ServableSketch::answer (header
    // parsed + row index built once at load). Quantify the win on a tall
    // sketch: cold = one-shot free functions (header parsed per query),
    // planned = the served path (cached header + row seek index).
    section("plan selection: tall matrix (20000 x 100) row/top-k");
    {
        let tall = synthetic_cf(&SyntheticConfig { m: 20_000, n: 100, ..Default::default() })
            .to_csr();
        let s_tall = (tall.nnz() as u64) / 10;
        let plan = SketchPlan::new(DistributionKind::Bernstein, s_tall).with_seed(3);
        let sk = sketch_offline(&tall, &plan).unwrap();
        let enc = encode_sketch(&sk).unwrap();
        let servable = ServableSketch::new(enc.clone(), plan.kind.name()).unwrap();
        let mut rng = Rng::new(0x7A11);
        let rows: Vec<u32> = (0..64).map(|_| rng.usize_below(tall.m) as u32).collect();
        let per = rows.len() as f64;

        bench_items("row_slice_cold_one_shot", budget, per, || {
            rows.iter().map(|&i| serve::row_slice(&enc, i).unwrap().len()).sum::<usize>()
        })
        .report();
        bench_items("row_slice_planned", budget, per, || {
            rows.iter()
                .map(|&i| match servable.answer(&QueryRequest::Row(i)).unwrap() {
                    QueryResponse::Entries(es) => es.len(),
                    _ => unreachable!("row answers are entry lists"),
                })
                .sum::<usize>()
        })
        .report();

        bench_items("top_10_cold_one_shot", budget, 1.0, || {
            serve::top_k(&enc, 10).unwrap()
        })
        .report();
        bench_items("top_10_planned", budget, 1.0, || {
            servable.answer(&QueryRequest::TopK(10)).unwrap()
        })
        .report();

        // row-parallel matvec on the same tall sketch: one query at a
        // time, split across the pool via the per-row offset index with
        // a deterministic in-order reduction (answers bit-identical to
        // the sequential scan) — the speedup from workers=1 to 4 is the
        // decode-path scaling story reported in decode_throughput.*
        section("row-parallel matvec: tall sketch (20000 x 100) worker scaling");
        let tall_served = Arc::new(servable);
        let xs_tall: Vec<f64> = (0..tall.n).map(|_| rng.normal()).collect();
        for workers in [1usize, 2, 4] {
            let server = QueryServer::start_with(Arc::clone(&tall_served), workers, 1);
            bench_items(
                &format!("matvec_split_workers={workers}"),
                budget,
                sk.nnz() as f64,
                || server.submit(QueryRequest::Matvec(xs_tall.clone())).wait().unwrap(),
            )
            .report();
            server.shutdown();
        }
    }

    section("QueryServer: concurrent matvec readers (Bernstein)");
    let servable =
        Arc::new(ServableSketch::new(enc, DistributionKind::Bernstein.name()).unwrap());
    for readers in [1usize, 2, 4, 8] {
        let queries = 32usize;
        bench_items(
            &format!("server_readers={readers}"),
            budget,
            queries as f64,
            || {
                let server = QueryServer::start(Arc::clone(&servable), readers);
                let pending =
                    server.submit_batch(vec![QueryRequest::Matvec(x.clone()); queries]);
                for p in pending {
                    p.wait().unwrap();
                }
                server.shutdown().total()
            },
        )
        .report();
    }

    // every served query records one latency-histogram sample plus a
    // couple of relaxed counters in the worker loop; with the registry
    // disabled the workers skip the Instant reads entirely. The ratio of
    // the two medians is the instrumentation cost on the hot path.
    section("obs overhead: served matvec, telemetry recording vs disabled");
    {
        let reg = matsketch::obs::global();
        let queries = 32usize;
        let mut qps = [0.0f64; 2]; // [recording, disabled]
        for (slot, enabled) in [(0usize, true), (1usize, false)] {
            reg.set_enabled(enabled);
            let server = QueryServer::start(Arc::clone(&servable), 4);
            let r = bench_items(
                if enabled { "matvec_obs_recording" } else { "matvec_obs_disabled" },
                budget,
                queries as f64,
                || {
                    let pending =
                        server.submit_batch(vec![QueryRequest::Matvec(x.clone()); queries]);
                    for p in pending {
                        p.wait().unwrap();
                    }
                },
            );
            r.report();
            server.shutdown();
            qps[slot] = queries as f64 / r.median;
        }
        reg.set_enabled(true);
        let overhead_pct = (qps[1] / qps[0] - 1.0) * 100.0;
        println!(
            "obs overhead: recording {:.1} queries/s vs disabled {:.1} queries/s \
             ({overhead_pct:+.2}%, target <2%)",
            qps[0], qps[1]
        );

        // same workload under request tracing: disabled (one relaxed
        // load per query) vs the default 1-in-64 sampling, where the
        // chosen query pays a root span, the worker-side child spans,
        // and ring retention. Queries run one at a time through the
        // serving entry's own sampling pattern in both arms.
        section("trace overhead: served matvec, tracing disabled vs 1-in-64 sampling");
        let tr = trace::global();
        tr.set_one_in_n(64);
        let mut tqps = [0.0f64; 2]; // [disabled, sampled 1-in-64]
        for (slot, enabled) in [(0usize, false), (1usize, true)] {
            tr.set_enabled(enabled);
            let server = QueryServer::start(Arc::clone(&servable), 4);
            let r = bench_items(
                if enabled { "matvec_trace_1_in_64" } else { "matvec_trace_disabled" },
                budget,
                queries as f64,
                || {
                    for _ in 0..queries {
                        match trace::sample() {
                            0 => {
                                server.submit(QueryRequest::Matvec(x.clone())).wait().unwrap();
                            }
                            id => {
                                let active = trace::ActiveTrace::begin(id);
                                let mut root = active.span(0, "request");
                                root.note("op", "matvec");
                                let ctx = root.ctx();
                                server
                                    .submit_traced(QueryRequest::Matvec(x.clone()), Some(ctx))
                                    .wait()
                                    .unwrap();
                                root.finish();
                                trace::finish(&active);
                            }
                        }
                    }
                },
            );
            r.report();
            server.shutdown();
            tqps[slot] = queries as f64 / r.median;
        }
        tr.set_enabled(true);
        tr.clear();
        let trace_overhead_pct = (tqps[0] / tqps[1] - 1.0) * 100.0;
        println!(
            "trace overhead: 1-in-64 sampling {:.1} queries/s vs disabled {:.1} queries/s \
             ({trace_overhead_pct:+.2}%, target <2%)",
            tqps[1], tqps[0]
        );

        let out = out_dir();
        std::fs::create_dir_all(&out).expect("create bench output dir");
        let json: Vec<(&str, Json)> = vec![
            ("matvec_obs_recording_qps", num(qps[0])),
            ("matvec_obs_disabled_qps", num(qps[1])),
            ("obs_overhead_pct", num(overhead_pct)),
            ("matvec_trace_disabled_qps", num(tqps[0])),
            ("matvec_trace_sampled_qps", num(tqps[1])),
            ("trace_overhead_pct", num(trace_overhead_pct)),
        ];
        let json_path = out.join("BENCH_obs.json");
        std::fs::write(&json_path, obj(json).to_string()).expect("write BENCH_obs.json");
        println!("wrote {}", json_path.display());
    }
}

/// `--out DIR` (default `reports`), tolerated anywhere in the arg list.
fn out_dir() -> std::path::PathBuf {
    let mut args = std::env::args();
    while let Some(arg) = args.next() {
        if arg == "--out" {
            if let Some(dir) = args.next() {
                return dir.into();
            }
        }
    }
    "reports".into()
}
