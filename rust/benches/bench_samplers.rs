//! E5/§Perf — sampler micro-benchmarks: the O(1)-per-item costs behind
//! Theorem 4.2 (binomial draw per stream item, hypergeometric replay,
//! alias draws), the end-to-end reservoir throughput, and each sampler as
//! hosted by the unified `Sketcher` engine.

#[path = "common/mod.rs"]
mod common;

use common::{bench_items, default_budget, section};
use matsketch::datasets::{synthetic_cf, SyntheticConfig};
use matsketch::distributions::{DistributionKind, MatrixStats};
use matsketch::engine::{build_sketcher, PipelineConfig, SketchMode};
use matsketch::samplers::{binomial, hypergeometric, AliasTable, ParallelReservoir};
use matsketch::sketch::SketchPlan;
use matsketch::util::rng::Rng;

fn main() {
    let budget = default_budget();
    section("samplers: exact binomial");
    for (name, n, p) in [
        ("binomial_tiny_p(n=1e6,p=1e-6)", 1_000_000u64, 1e-6),
        ("binomial_small_mean(n=1e4,p=1e-3)", 10_000, 1e-3),
        ("binomial_large_mean(n=1e6,p=0.01)", 1_000_000, 0.01),
    ] {
        let mut rng = Rng::new(1);
        let draws = 100_000usize;
        bench_items(name, budget, draws as f64, || {
            let mut acc = 0u64;
            for _ in 0..draws {
                acc += binomial(&mut rng, n, p);
            }
            acc
        })
        .report();
    }

    section("samplers: hypergeometric");
    let mut rng = Rng::new(2);
    let draws = 100_000usize;
    bench_items("hypergeometric(s=1e4,l=3e3,k=50)", budget, draws as f64, || {
        let mut acc = 0u64;
        for _ in 0..draws {
            acc += hypergeometric(&mut rng, 10_000, 3_000, 50);
        }
        acc
    })
    .report();

    section("samplers: alias table");
    let mut wrng = Rng::new(3);
    let weights: Vec<f64> = (0..1_000_000).map(|_| wrng.f64_open()).collect();
    let table = AliasTable::new(&weights);
    let mut rng = Rng::new(4);
    let draws = 1_000_000usize;
    bench_items("alias_sample(1M buckets)", budget, draws as f64, || {
        let mut acc = 0usize;
        for _ in 0..draws {
            acc ^= table.sample(&mut rng);
        }
        acc
    })
    .report();

    section("samplers: Appendix-A reservoir (Theorem 4.2)");
    for s in [1_000u64, 100_000] {
        let items = 2_000_000usize;
        bench_items(
            &format!("reservoir_push(s={s}, {items} items)"),
            budget,
            items as f64,
            || {
                let mut r = ParallelReservoir::new(s, 7);
                for i in 0..items {
                    r.push(i as u32, 1.0 + (i % 17) as f64);
                }
                r.sketch_len()
            },
        )
        .report();
    }
    let items = 500_000usize;
    bench_items("reservoir_push_finalize(s=10k)", budget, items as f64, || {
        let mut r = ParallelReservoir::new(10_000, 9);
        for i in 0..items {
            r.push(i as u32, 1.0 + (i % 13) as f64);
        }
        r.finalize().len()
    })
    .report();

    section("samplers behind the Sketcher trait (ingest+finalize, s=nnz/10)");
    let a = synthetic_cf(&SyntheticConfig { m: 100, n: 20_000, ..Default::default() });
    let stats = MatrixStats::from_coo(&a);
    let nnz = a.nnz() as f64;
    for mode in SketchMode::all() {
        let plan =
            SketchPlan::new(DistributionKind::Bernstein, (nnz as u64) / 10).with_seed(5);
        bench_items(&format!("sketcher_{}(nnz={})", mode.name(), a.nnz()), budget, nnz, || {
            let mut sk =
                build_sketcher(mode, &stats, &plan, &PipelineConfig::default()).unwrap();
            for chunk in a.entries.chunks(4096) {
                sk.ingest(chunk).unwrap();
            }
            sk.finalize().unwrap().0.nnz()
        })
        .report();
    }
}
