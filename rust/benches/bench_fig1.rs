//! E2 — regenerates Figure 1 (left/right projection quality vs log10(s),
//! 6 methods × 4 matrices) and times the per-dataset sweep.
//! `MATSKETCH_BENCH_FULL=1` runs the full-scale datasets; default uses the
//! small variants so `cargo bench` completes in minutes.

#[path = "common/mod.rs"]
mod common;

use std::time::Instant;

use common::section;
use matsketch::datasets::DatasetId;
use matsketch::eval::figure1::{figure1_dataset, write_figure1, Figure1Config};
use matsketch::runtime::default_engine;

fn main() {
    let full = std::env::var("MATSKETCH_BENCH_FULL").is_ok();
    let engine = default_engine();
    let cfg = Figure1Config {
        k: if full { 20 } else { 12 },
        svd_iters: 8,
        budget_points: if full { 8 } else { 5 },
        seed: 0,
        small: !full,
        ..Default::default()
    };
    section(&format!(
        "E2: Figure 1 sweep (engine={}, scale={})",
        engine.name(),
        if full { "full" } else { "small" }
    ));
    let mut all = Vec::new();
    for id in DatasetId::all() {
        let coo = if full { id.generate(cfg.seed) } else { id.generate_small(cfg.seed) };
        let a = coo.to_csr();
        let t0 = Instant::now();
        let pts = figure1_dataset(id.name(), &a, &cfg, engine.as_ref()).unwrap();
        println!(
            "bench figure1_{:<42} {:>12.2} s ({} points)",
            id.name(),
            t0.elapsed().as_secs_f64(),
            pts.len()
        );
        // per-dataset winner summary at the largest budget
        let max_s = pts.iter().map(|p| p.s).max().unwrap();
        let mut at_max: Vec<_> = pts.iter().filter(|p| p.s == max_s).collect();
        at_max.sort_by(|x, y| y.left.partial_cmp(&x.left).unwrap());
        println!("  at s={max_s}:");
        for p in &at_max {
            println!("    {:<14} left={:.3} right={:.3}", p.method, p.left, p.right);
        }
        all.extend(pts);
    }
    write_figure1(std::path::Path::new("reports"), &all).unwrap();
    println!("\nwrote reports/figure1.csv ({} points)", all.len());
}
