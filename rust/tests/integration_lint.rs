//! Integration: the `matsketch lint` analyzer — the shipped tree must be
//! lint-clean against the checked-in baseline, injected violations must
//! surface with `path:line [lint]` locations, and baseline rot (stale
//! `lint.allow` entries) must be reported rather than silently ignored.

use std::path::Path;

use matsketch::analysis::{self, baseline, LintConfig, SourceFile};

fn render_all(findings: &[analysis::Finding]) -> String {
    findings.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
}

#[test]
fn shipped_tree_is_lint_clean() {
    let cfg = LintConfig::locate(Path::new(env!("CARGO_MANIFEST_DIR")))
        .expect("crate root not found");
    let report = analysis::run(&cfg).expect("lint run failed");
    assert!(
        report.clean(),
        "lint findings on the shipped tree:\n{}",
        render_all(&report.findings)
    );
    assert!(
        report.stale_allow.is_empty(),
        "stale lint.allow entries:\n{}",
        report.stale_allow.iter().map(|e| e.render()).collect::<Vec<_>>().join("\n")
    );
    // the analyzer actually walked the tree (src + tests + benches)
    assert!(report.files_scanned > 50, "only {} files scanned", report.files_scanned);
}

#[test]
fn shipped_baseline_is_load_bearing() {
    // every `lint.allow` entry must both parse and accept a real finding
    // (stale entries are covered above); an empty baseline would mean
    // the file should be deleted.
    let cfg = LintConfig::locate(Path::new(env!("CARGO_MANIFEST_DIR"))).unwrap();
    let allow_path = cfg.allow.as_ref().expect("src/analysis/lint.allow missing");
    let entries = baseline::parse(&std::fs::read_to_string(allow_path).unwrap());
    assert!(!entries.is_empty());
    assert!(entries.iter().all(|e| !e.lint.is_empty() && !e.excerpt.is_empty()));
    let report = analysis::run(&cfg).unwrap();
    assert_eq!(report.baselined.len(), entries.len());
}

#[test]
fn injected_violations_surface_with_location() {
    let bad = SourceFile::new(
        "src/sketch/bitio.rs",
        "fn read(buf: &[u8]) -> u8 {\n    buf[3]\n}\n",
    );
    let report = analysis::analyze_sources(&[bad], None, &[]);
    assert!(!report.clean());
    let rendered = render_all(&report.findings);
    assert!(
        rendered.starts_with("src/sketch/bitio.rs:2 [panic-free-decode]"),
        "unexpected rendering: {rendered}"
    );
}

#[test]
fn injected_violations_span_every_lint() {
    let sources = [
        SourceFile::new("src/x.rs", "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n"),
        SourceFile::new(
            "src/obs/metrics.rs",
            "fn f(c: &std::sync::atomic::AtomicU64) {\n    \
             c.store(1, std::sync::atomic::Ordering::SeqCst);\n}\n",
        ),
        SourceFile::new(
            "src/net/wire.rs",
            "const OP_GHOST: u8 = 0x7F;\nfn f(v: &[u8]) -> u8 {\n    v[0]\n}\n",
        ),
        SourceFile::new("src/serve/live.rs", "fn f() {\n    let t = Instant::now();\n}\n"),
    ];
    let report = analysis::analyze_sources(&sources, Some("no wire table"), &[]);
    let mut lints: Vec<&str> = report.findings.iter().map(|f| f.lint).collect();
    lints.sort_unstable();
    lints.dedup();
    assert_eq!(
        lints,
        vec!["atomics-ordering", "panic-free-decode", "timed-gating", "unsafe-audit",
             "wire-discipline"],
        "full report:\n{}",
        render_all(&report.findings)
    );
}

#[test]
fn baseline_rot_is_detected() {
    let clean = SourceFile::new("src/x.rs", "fn f() {}\n");
    let allow = baseline::parse("timed-gating\tsrc/serve/live.rs\tlong gone line\n");
    let report = analysis::analyze_sources(&[clean], None, &allow);
    assert!(report.clean());
    assert_eq!(report.stale_allow.len(), 1);
    assert_eq!(
        report.stale_allow[0].render(),
        "timed-gating\tsrc/serve/live.rs\tlong gone line"
    );
}
