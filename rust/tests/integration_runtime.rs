//! Integration: the XLA engine (AOT artifacts via PJRT) cross-validated
//! against the pure-Rust engine on every DenseEngine op, plus an
//! end-to-end SVD comparison. Skipped gracefully when `artifacts/` has
//! not been built (`make artifacts`).

use matsketch::linalg::svd::topk_svd;
use matsketch::runtime::{DenseEngine, RustEngine, XlaEngine};
use matsketch::sparse::{Coo, Dense};
use matsketch::util::rng::Rng;

fn xla() -> Option<XlaEngine> {
    let dir = std::path::Path::new("artifacts");
    match XlaEngine::from_dir(dir) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping XLA integration test (artifacts not built): {err}");
            None
        }
    }
}

fn close(a: f64, b: f64, tol: f64, what: &str) {
    // relative tolerance with a small absolute floor: f32-accumulated
    // entries that are near zero (cancellation) otherwise dominate the
    // relative error even though they are exact to f32 resolution.
    let denom = a.abs().max(b.abs()).max(1e-9);
    assert!((a - b).abs() < tol * denom + 1e-4, "{what}: {a} vs {b}");
}

#[test]
fn gram_matches_rust_engine() {
    let Some(xla) = xla() else { return };
    let mut rng = Rng::new(0);
    for rows in [100usize, 256, 300, 2048, 3000] {
        for k in [4usize, 20, 32] {
            let y = Dense::randn(rows, k, &mut rng);
            let g1 = xla.gram(&y).unwrap();
            let g2 = RustEngine.gram(&y).unwrap();
            for i in 0..k * k {
                close(g1[i], g2[i], 1e-3, &format!("gram[{i}] rows={rows} k={k}"));
            }
        }
    }
}

#[test]
fn apply_matches_rust_engine() {
    let Some(xla) = xla() else { return };
    let mut rng = Rng::new(1);
    for rows in [64usize, 256, 1000] {
        let k = 20;
        let y = Dense::randn(rows, k, &mut rng);
        let t: Vec<f64> = (0..k * k).map(|_| rng.normal() * 0.3).collect();
        let q1 = xla.apply(&y, &t).unwrap();
        let q2 = RustEngine.apply(&y, &t).unwrap();
        assert_eq!(q1.rows, rows);
        assert_eq!(q1.cols, k);
        for i in 0..rows * k {
            close(q1.data[i] as f64, q2.data[i] as f64, 2e-3, "apply");
        }
    }
}

#[test]
fn proj_matches_rust_engine_with_col_windows() {
    let Some(xla) = xla() else { return };
    let mut rng = Rng::new(2);
    // cols > artifact C (512) forces column windowing
    let (rows, k, cols) = (700usize, 24usize, 1200usize);
    let q = Dense::randn(rows, k, &mut rng);
    let a = Dense::randn(rows, cols, &mut rng);
    let p1 = xla.proj(&q, &a).unwrap();
    let p2 = RustEngine.proj(&q, &a).unwrap();
    assert_eq!(p1.rows, k);
    assert_eq!(p1.cols, cols);
    for i in 0..k * cols {
        close(p1.data[i] as f64, p2.data[i] as f64, 5e-3, "proj");
    }
}

#[test]
fn power_iter_matches_rust_engine() {
    let Some(xla) = xla() else { return };
    let mut rng = Rng::new(3);
    for k in [2usize, 8, 32] {
        // PSD matrix
        let mfac = Dense::randn(k, k, &mut rng);
        let g = RustEngine.gram(&mfac).unwrap();
        let (l1, _v1) = xla.power_iter(&g, k).unwrap();
        let (l2, _v2) = RustEngine.power_iter(&g, k).unwrap();
        close(l1, l2, 1e-3, &format!("power_iter k={k}"));
    }
}

#[test]
fn probs_matches_rust_engine() {
    let Some(xla) = xla() else { return };
    let mut rng = Rng::new(4);
    let (rows, cols) = (300usize, 700usize);
    let a = Dense::randn(rows, cols, &mut rng);
    let w: Vec<f32> = (0..rows).map(|_| rng.f32() + 0.01).collect();
    for power in [1u8, 2] {
        let p1 = xla.probs(&a, &w, power).unwrap();
        let p2 = RustEngine.probs(&a, &w, power).unwrap();
        for i in 0..rows * cols {
            close(p1.data[i] as f64, p2.data[i] as f64, 1e-4, "probs");
        }
    }
}

#[test]
fn svd_through_xla_engine_matches_rust() {
    let Some(xla) = xla() else { return };
    let mut rng = Rng::new(5);
    let mut coo = Coo::new(80, 400);
    for i in 0..80u32 {
        for _ in 0..30 {
            coo.push(i, rng.usize_below(400) as u32, rng.normal() as f32);
        }
    }
    coo.normalize();
    let a = coo.to_csr();
    let s_xla = topk_svd(&a, 6, 10, 7, &xla).unwrap();
    let s_rust = topk_svd(&a, 6, 10, 7, &RustEngine).unwrap();
    for (x, r) in s_xla.sigma.iter().zip(s_rust.sigma.iter()) {
        close(*x, *r, 1e-2, "singular value");
    }
}
