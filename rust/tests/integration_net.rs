//! Integration: the network front's *protocol* behaviour — the
//! malformed-frame corpus (now including a bad batch-count frame,
//! cross-version traffic, the v3 generation cases — a future pin is a
//! typed fault that keeps the connection, a v2 frame is answered at v2 —
//! and the v5 trace-word skew cases) never kills the server, shutdown is
//! graceful, and handle scoping is enforced. Backend answer equivalence lives in the parameterized suite
//! in `integration_api.rs`.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::time::Duration;

use matsketch::api::{QueryRequest, QueryResponse, RemoteClient, SketchClient};
use matsketch::distributions::DistributionKind;
use matsketch::engine::{self, PipelineConfig, SketchMode};
use matsketch::net::wire::{self, FRAME_HEADER_LEN, WIRE_MAGIC, WIRE_VERSION};
use matsketch::net::{ErrCode, NetServer, NetServerConfig, Response};
use matsketch::serve::{coo_fingerprint, SketchStore, StoreKey};
use matsketch::sketch::{encode_sketch, SketchPlan};
use matsketch::sparse::Coo;
use matsketch::util::rng::Rng;

const BUDGET: u64 = 600;
const SEED: u64 = 21;

fn fixed_matrix() -> Coo {
    let mut rng = Rng::new(0x7E57_4E7);
    let mut coo = Coo::new(24, 160);
    for i in 0..24u32 {
        for _ in 0..12 {
            coo.push(i, rng.usize_below(160) as u32, (rng.normal() as f32) + 1.5);
        }
    }
    coo.normalize();
    coo
}

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("matsketch_net_itest_{tag}_{}", std::process::id()))
}

/// Build + persist one Bernstein sketch, returning its key.
fn populate_store(store: &SketchStore) -> StoreKey {
    let coo = fixed_matrix();
    let fp = coo_fingerprint(&coo);
    let plan = SketchPlan::new(DistributionKind::Bernstein, BUDGET).with_seed(SEED);
    let (sk, _) = engine::sketch_coo(
        SketchMode::Offline,
        &coo,
        &plan,
        &PipelineConfig::default(),
    )
    .unwrap();
    let enc = encode_sketch(&sk).unwrap();
    let key = StoreKey::new("fixed", &sk.method, BUDGET, SEED).with_fingerprint(fp);
    store.put(&key, &enc).unwrap();
    key
}

fn start_server(store_dir: &Path, max_connections: usize) -> NetServer {
    NetServer::bind(
        SketchStore::open(store_dir).unwrap(),
        "127.0.0.1:0",
        NetServerConfig {
            workers_per_sketch: 2,
            max_connections,
            read_timeout: Some(Duration::from_secs(10)),
            write_timeout: Some(Duration::from_secs(10)),
            ..Default::default()
        },
    )
    .unwrap()
}

fn raw_header(magic: [u8; 4], version: u16, opcode: u8, request_id: u64, len: u32) -> Vec<u8> {
    let mut h = Vec::with_capacity(FRAME_HEADER_LEN);
    h.extend_from_slice(&magic);
    h.extend_from_slice(&version.to_be_bytes());
    h.push(opcode);
    h.push(0);
    h.extend_from_slice(&request_id.to_be_bytes());
    h.extend_from_slice(&len.to_be_bytes());
    h
}

/// Read one response frame off a raw socket.
fn read_raw_response(stream: &mut TcpStream) -> Option<(u64, Response)> {
    let header = wire::read_frame_header(stream).ok()??;
    let h = wire::parse_frame_header(&header).ok()?;
    let payload = wire::read_payload(stream, h.len).ok()?;
    Some((h.request_id, wire::decode_response(h.version, h.opcode, &payload).ok()?))
}

fn expect_error_code(stream: &mut TcpStream, want: ErrCode, what: &str) {
    match read_raw_response(stream) {
        Some((_, Response::Error { code, .. })) => assert_eq!(code, want, "{what}"),
        other => panic!("{what}: expected typed error, got {other:?}"),
    }
}

/// Acceptance: the malformed-frame corpus — truncated length, bad magic,
/// wrong version, giant declared length, mid-payload disconnect, a batch
/// count the payload cannot hold, v5 trace-word skew in both directions —
/// never kills the server; it answers subsequent requests normally.
#[test]
fn malformed_frame_corpus_never_kills_the_server() {
    let dir = tmp_dir("malformed");
    let _ = std::fs::remove_dir_all(&dir);
    let key = populate_store(&SketchStore::open(&dir).unwrap());
    let server = start_server(&dir, 16);
    let addr = server.local_addr();

    let assert_alive = |what: &str| {
        let mut client = RemoteClient::connect(&addr.to_string()).unwrap();
        client.ping().unwrap_or_else(|e| panic!("after {what}: ping failed: {e}"));
        match client.query(&key, &QueryRequest::TopK(3)) {
            Ok(QueryResponse::Entries(es)) => assert_eq!(es.len(), 3, "after {what}"),
            other => panic!("after {what}: top-3 answered {other:?}"),
        }
    };

    // 1. truncated frame header: 10 of 20 bytes, then disconnect
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let good = wire::encode_request(1, &matsketch::net::Request::Ping);
        s.write_all(&good[..10]).unwrap();
        drop(s);
    }
    assert_alive("truncated header");

    // 2. bad magic: typed malformed error, then the server closes
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(&raw_header(*b"JUNK", WIRE_VERSION, 0x01, 5, 0)).unwrap();
        expect_error_code(&mut s, ErrCode::Malformed, "bad magic");
        // connection is closed after a frame fault
        let mut rest = Vec::new();
        let _ = s.read_to_end(&mut rest);
        assert!(rest.is_empty(), "no stray bytes after the error frame");
    }
    assert_alive("bad magic");

    // 3. wrong protocol version (newer than the server speaks)
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(&raw_header(WIRE_MAGIC, WIRE_VERSION + 7, 0x01, 6, 0)).unwrap();
        expect_error_code(&mut s, ErrCode::BadVersion, "wrong version");
    }
    assert_alive("wrong version");

    // 4. giant declared payload length
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(&raw_header(WIRE_MAGIC, WIRE_VERSION, 0x01, 7, u32::MAX)).unwrap();
        expect_error_code(&mut s, ErrCode::Oversized, "giant length");
    }
    assert_alive("giant length");

    // 5. mid-payload disconnect: a valid matvec frame cut short
    {
        let mut s = TcpStream::connect(addr).unwrap();
        let frame = wire::encode_request(
            8,
            &matsketch::net::Request::Query {
                handle: 0,
                pin: 0,
                trace: 0,
                query: QueryRequest::Matvec(vec![1.0; 64]),
            },
        );
        s.write_all(&frame[..FRAME_HEADER_LEN + 11]).unwrap();
        drop(s);
    }
    assert_alive("mid-payload disconnect");

    // 6. unknown opcode: typed error, and the SAME connection keeps
    // working afterwards (payload faults do not cost the connection)
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(&raw_header(WIRE_MAGIC, WIRE_VERSION, 0x6F, 9, 0)).unwrap();
        expect_error_code(&mut s, ErrCode::UnknownOpcode, "unknown opcode");
        let ping = wire::encode_request(10, &matsketch::net::Request::Ping);
        s.write_all(&ping).unwrap();
        match read_raw_response(&mut s) {
            Some((10, Response::Pong)) => {}
            other => panic!("same-connection ping after payload fault: {other:?}"),
        }
    }
    assert_alive("unknown opcode");

    // 7. bad batch count: a MatvecBatch frame (opcode 0x15) declaring a
    // million vectors in a 12-byte payload — typed malformed error, and
    // the connection survives (it's a payload fault)
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut payload = Vec::new();
        payload.extend_from_slice(&0u32.to_be_bytes()); // handle
        payload.extend_from_slice(&1_000_000u32.to_be_bytes()); // batch count
        payload.extend_from_slice(&0u32.to_be_bytes()); // one stray length
        let mut frame = raw_header(WIRE_MAGIC, WIRE_VERSION, 0x15, 11, payload.len() as u32);
        frame.extend_from_slice(&payload);
        s.write_all(&frame).unwrap();
        expect_error_code(&mut s, ErrCode::Malformed, "bad batch count");
        let ping = wire::encode_request(12, &matsketch::net::Request::Ping);
        s.write_all(&ping).unwrap();
        match read_raw_response(&mut s) {
            Some((12, Response::Pong)) => {}
            other => panic!("same-connection ping after bad batch count: {other:?}"),
        }
    }
    assert_alive("bad batch count");

    // 8. version skew: a v1-marked Ping is still served (answered at v1),
    // while the v2-only MatvecBatch opcode under v1 is a typed
    // unknown-opcode fault
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(&raw_header(WIRE_MAGIC, 1, 0x01, 13, 0)).unwrap();
        let header = wire::read_frame_header(&mut s).unwrap().unwrap();
        assert_eq!(u16::from_be_bytes([header[4], header[5]]), 1, "reply echoes v1");
        let h = wire::parse_frame_header(&header).unwrap();
        let payload = wire::read_payload(&mut s, h.len).unwrap();
        assert!(matches!(
            wire::decode_response(h.version, h.opcode, &payload).unwrap(),
            Response::Pong
        ));

        let mut payload = Vec::new();
        payload.extend_from_slice(&0u32.to_be_bytes()); // handle
        payload.extend_from_slice(&0u32.to_be_bytes()); // empty batch
        let mut frame = raw_header(WIRE_MAGIC, 1, 0x15, 14, payload.len() as u32);
        frame.extend_from_slice(&payload);
        s.write_all(&frame).unwrap();
        expect_error_code(&mut s, ErrCode::UnknownOpcode, "v2 opcode in v1 frame");
    }
    assert_alive("version skew");

    // 9. future generation pin: a frozen store sketch only serves
    // generation 0, so a v3 query pinned to generation 9 is a typed
    // generation fault — a *payload* fault, so the same connection keeps
    // answering afterwards, and GenPoll reports generation 0 immediately
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let open =
            wire::encode_request(20, &matsketch::net::Request::OpenSketch(key.clone()));
        s.write_all(&open).unwrap();
        let handle = match read_raw_response(&mut s) {
            Some((20, Response::SketchOpened { handle, .. })) => handle,
            other => panic!("open for the pinned query: {other:?}"),
        };
        let pinned = matsketch::net::Request::Query {
            handle,
            pin: 9,
            trace: 0,
            query: QueryRequest::TopK(1),
        };
        assert_eq!(wire::request_version(&pinned), 3, "a nonzero pin forces a v3 frame");
        s.write_all(&wire::encode_request(21, &pinned)).unwrap();
        expect_error_code(&mut s, ErrCode::Generation, "future generation pin");
        let poll = wire::encode_request(
            22,
            &matsketch::net::Request::GenPoll { handle, min_gen: 5, timeout_ms: 50 },
        );
        s.write_all(&poll).unwrap();
        match read_raw_response(&mut s) {
            Some((22, Response::Generation(0))) => {}
            other => panic!("GenPoll on a frozen sketch: {other:?}"),
        }
        let ping = wire::encode_request(23, &matsketch::net::Request::Ping);
        s.write_all(&ping).unwrap();
        match read_raw_response(&mut s) {
            Some((23, Response::Pong)) => {}
            other => panic!("same-connection ping after generation fault: {other:?}"),
        }
    }
    assert_alive("future generation pin");

    // 10. v2 frame with no generation field: still answered, the reply
    // echoes v2, and the answer decodes as generation 0 — the generation
    // tag only exists on the wire at v3
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let open =
            wire::encode_request(24, &matsketch::net::Request::OpenSketch(key.clone()));
        s.write_all(&open).unwrap();
        let handle = match read_raw_response(&mut s) {
            Some((24, Response::SketchOpened { handle, .. })) => handle,
            other => panic!("open for the v2 query: {other:?}"),
        };
        let batch = matsketch::net::Request::Query {
            handle,
            pin: 0,
            trace: 0,
            query: QueryRequest::MatvecBatch(vec![vec![0.25; 160]]),
        };
        assert_eq!(wire::request_version(&batch), 2, "unpinned batch stays a v2 frame");
        s.write_all(&wire::encode_request(25, &batch)).unwrap();
        let header = wire::read_frame_header(&mut s).unwrap().unwrap();
        assert_eq!(u16::from_be_bytes([header[4], header[5]]), 2, "reply echoes v2");
        let h = wire::parse_frame_header(&header).unwrap();
        let payload = wire::read_payload(&mut s, h.len).unwrap();
        match wire::decode_response(h.version, h.opcode, &payload).unwrap() {
            Response::Answer { generation: 0, answer: QueryResponse::Vectors(ys) } => {
                assert_eq!(ys.len(), 1);
            }
            other => panic!("v2 batch answer: {other:?}"),
        }
    }
    assert_alive("v2 frame without generation");

    // 11. trace bytes on a pre-trace frame: a v4-marked top-k query
    // carrying the v5 trace word is 8 bytes of trailing garbage to a v4
    // decoder — typed malformed error, connection survives
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut payload = Vec::new();
        payload.extend_from_slice(&0u32.to_be_bytes()); // handle
        payload.extend_from_slice(&0u64.to_be_bytes()); // pin (v3+)
        payload.extend_from_slice(&1u64.to_be_bytes()); // k
        payload.extend_from_slice(&7u64.to_be_bytes()); // stray trace word
        let mut frame = raw_header(WIRE_MAGIC, 4, 0x14, 26, payload.len() as u32);
        frame.extend_from_slice(&payload);
        s.write_all(&frame).unwrap();
        expect_error_code(&mut s, ErrCode::Malformed, "trace word in v4 frame");
        let ping = wire::encode_request(27, &matsketch::net::Request::Ping);
        s.write_all(&ping).unwrap();
        match read_raw_response(&mut s) {
            Some((27, Response::Pong)) => {}
            other => panic!("same-connection ping after stray trace word: {other:?}"),
        }
    }
    assert_alive("trace word in v4 frame");

    // 12. v5 frame truncated before its trace word: handle + pin only
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut payload = Vec::new();
        payload.extend_from_slice(&0u32.to_be_bytes()); // handle
        payload.extend_from_slice(&0u64.to_be_bytes()); // pin — then nothing
        let mut frame =
            raw_header(WIRE_MAGIC, WIRE_VERSION, 0x14, 28, payload.len() as u32);
        frame.extend_from_slice(&payload);
        s.write_all(&frame).unwrap();
        expect_error_code(&mut s, ErrCode::Malformed, "v5 frame without trace word");
    }
    assert_alive("v5 frame without trace word");

    // 13. the v5-only TraceDump opcode under v1 is a typed unknown-opcode
    // fault, exactly like the v2-opcode-in-v1-frame case
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut payload = Vec::new();
        payload.extend_from_slice(&0u64.to_be_bytes()); // id
        payload.extend_from_slice(&5u32.to_be_bytes()); // slowest
        let mut frame = raw_header(WIRE_MAGIC, 1, 0x06, 29, payload.len() as u32);
        frame.extend_from_slice(&payload);
        s.write_all(&frame).unwrap();
        expect_error_code(&mut s, ErrCode::UnknownOpcode, "TraceDump in v1 frame");
    }
    assert_alive("TraceDump in v1 frame");

    let stats = server.shutdown();
    assert!(stats.faults >= 11, "typed faults recorded: {}", stats.faults);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The wire Shutdown sentinel winds the whole server down gracefully.
#[test]
fn shutdown_sentinel_stops_the_server() {
    let dir = tmp_dir("sentinel");
    let _ = std::fs::remove_dir_all(&dir);
    populate_store(&SketchStore::open(&dir).unwrap());
    let server = start_server(&dir, 16);
    let addr = server.local_addr();

    let mut client = RemoteClient::connect(&addr.to_string()).unwrap();
    client.ping().unwrap();
    client.shutdown_server().unwrap();

    // wait() returns because the sentinel triggered teardown
    let stats = server.wait();
    assert!(stats.frames >= 2);

    // the port no longer accepts wire traffic
    let refused = match TcpStream::connect_timeout(&addr, Duration::from_millis(500)) {
        Err(_) => true,
        Ok(mut s) => {
            // a racing accept may still succeed; the server must not
            // answer a ping on it
            let ping = wire::encode_request(1, &matsketch::net::Request::Ping);
            let _ = s.write_all(&ping);
            !matches!(read_raw_response(&mut s), Some((_, Response::Pong)))
        }
    };
    assert!(refused, "server still answering after shutdown");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The v4 `Stats` opcode: telemetry snapshots are monotone
/// non-decreasing across queries, the scraped deltas cover this client's
/// own traffic, and a typed fault bumps exactly the matching per-code
/// counter. All assertions are `>=` / monotone: the obs registry is
/// process-global, so the other tests in this binary record into the
/// same counters concurrently — pollution can inflate a reading, never
/// deflate it.
#[test]
fn stats_snapshots_are_monotone_and_faults_count_per_code() {
    let dir = tmp_dir("stats");
    let _ = std::fs::remove_dir_all(&dir);
    let key = populate_store(&SketchStore::open(&dir).unwrap());
    let server = start_server(&dir, 16);
    let addr = server.local_addr();

    let mut client = RemoteClient::connect(&addr.to_string()).unwrap();
    let before = client.stats().unwrap();

    for _ in 0..4 {
        match client.query(&key, &QueryRequest::TopK(2)) {
            Ok(QueryResponse::Entries(es)) => assert_eq!(es.len(), 2),
            other => panic!("top-2 under stats test: {other:?}"),
        }
    }
    let mid = client.stats().unwrap();
    assert!(
        mid.counter("req_top_k") >= before.counter("req_top_k") + 4,
        "4 top-k queries counted: {} -> {}",
        before.counter("req_top_k"),
        mid.counter("req_top_k")
    );
    assert!(
        mid.hist_count("exec_top_k_us") >= before.hist_count("exec_top_k_us") + 4,
        "4 top-k executions in the latency histogram"
    );
    assert!(mid.counter("req_stats") >= before.counter("req_stats") + 1);
    assert!(mid.counter("net_bytes_in") > before.counter("net_bytes_in"));
    assert!(mid.counter("net_bytes_out") > before.counter("net_bytes_out"));

    // a bad-handle query is a typed fault and lands on ITS counter
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let frame = wire::encode_request(
            30,
            &matsketch::net::Request::Query {
                handle: 99,
                pin: 0,
                trace: 0,
                query: QueryRequest::TopK(1),
            },
        );
        s.write_all(&frame).unwrap();
        expect_error_code(&mut s, ErrCode::BadHandle, "stats-test bad handle");
    }
    let after = client.stats().unwrap();
    assert!(
        after.counter("fault_bad_handle") >= mid.counter("fault_bad_handle") + 1,
        "bad-handle fault counted per code"
    );

    // every counter and histogram is monotone across the three scrapes,
    // and the diffs therefore never underflow
    for (earlier, later) in [(&before, &mid), (&mid, &after)] {
        for (name, v) in &earlier.counters {
            assert!(later.counter(name) >= *v, "counter {name} went backwards");
        }
        for (name, _) in &earlier.hists {
            assert!(
                later.hist_count(name) >= earlier.hist_count(name),
                "hist {name} went backwards"
            );
        }
    }
    let delta = after.diff(&before);
    assert!(delta.counter("req_top_k") >= 4);

    client.close().unwrap();
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Handles are connection-scoped: a fresh connection cannot query with a
/// stale handle, and the error is typed.
#[test]
fn unopened_handle_is_a_typed_error() {
    let dir = tmp_dir("badhandle");
    let _ = std::fs::remove_dir_all(&dir);
    populate_store(&SketchStore::open(&dir).unwrap());
    let server = start_server(&dir, 16);
    let addr = server.local_addr();

    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let frame = wire::encode_request(
        3,
        &matsketch::net::Request::Query {
            handle: 42,
            pin: 0,
            trace: 0,
            query: QueryRequest::TopK(1),
        },
    );
    s.write_all(&frame).unwrap();
    expect_error_code(&mut s, ErrCode::BadHandle, "unopened handle");
    // and an open for an absent sketch is a typed store error
    let missing = StoreKey::new("no-such-dataset", "Bernstein", 1, 0);
    let frame = wire::encode_request(4, &matsketch::net::Request::OpenSketch(missing));
    s.write_all(&frame).unwrap();
    expect_error_code(&mut s, ErrCode::Store, "absent sketch");
    drop(s);

    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
