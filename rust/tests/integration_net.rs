//! Integration: the network serving front. A live loopback server must
//! answer every query kind **byte-identically** to the in-process
//! `QueryServer` for every Figure-1 distribution, stay healthy under
//! concurrent clients, and survive the malformed-frame corpus.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use matsketch::distributions::DistributionKind;
use matsketch::engine::{self, PipelineConfig, SketchMode};
use matsketch::net::wire::{self, FRAME_HEADER_LEN, WIRE_MAGIC, WIRE_VERSION};
use matsketch::net::{ErrCode, NetServer, NetServerConfig, RemoteSketchClient, Response};
use matsketch::serve::{
    coo_fingerprint, Query, QueryOutcome, QueryServer, ServableSketch, SketchStore, StoreKey,
};
use matsketch::sketch::{encode_sketch, SketchPlan};
use matsketch::sparse::Coo;
use matsketch::util::rng::Rng;

const BUDGET: u64 = 600;
const SEED: u64 = 21;

fn fixed_matrix() -> Coo {
    let mut rng = Rng::new(0x7E57_4E7);
    let mut coo = Coo::new(24, 160);
    for i in 0..24u32 {
        for _ in 0..12 {
            coo.push(i, rng.usize_below(160) as u32, (rng.normal() as f32) + 1.5);
        }
    }
    coo.normalize();
    coo
}

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("matsketch_net_itest_{tag}_{}", std::process::id()))
}

/// Build + persist one sketch per Figure-1 distribution, returning the
/// keys plus in-process reference sketches loaded back from the store
/// (the same path the server takes).
fn populate_store(store: &SketchStore) -> Vec<(StoreKey, Arc<ServableSketch>)> {
    let coo = fixed_matrix();
    let fp = coo_fingerprint(&coo);
    let mut out = Vec::new();
    for kind in DistributionKind::figure1_set() {
        let plan = SketchPlan::new(kind, BUDGET).with_seed(SEED);
        let (sk, _) = engine::sketch_coo(
            SketchMode::Offline,
            &coo,
            &plan,
            &PipelineConfig::default(),
        )
        .unwrap();
        let enc = encode_sketch(&sk).unwrap();
        let key = StoreKey::new("fixed", &sk.method, BUDGET, SEED).with_fingerprint(fp);
        store.put(&key, &enc).unwrap();
        let reference =
            Arc::new(ServableSketch::from_stored(store.get(&key).unwrap().unwrap()).unwrap());
        out.push((key, reference));
    }
    out
}

fn start_server(store_dir: &Path, max_connections: usize) -> NetServer {
    NetServer::bind(
        SketchStore::open(store_dir).unwrap(),
        "127.0.0.1:0",
        NetServerConfig {
            workers_per_sketch: 2,
            max_connections,
            read_timeout: Some(Duration::from_secs(10)),
            write_timeout: Some(Duration::from_secs(10)),
        },
    )
    .unwrap()
}

/// Exact f64-bit equality: what "byte-identical over the wire" means
/// after decoding.
fn assert_bit_identical(got: &QueryOutcome, want: &QueryOutcome, what: &str) {
    match (got, want) {
        (QueryOutcome::Vector(a), QueryOutcome::Vector(b)) => {
            assert_eq!(a.len(), b.len(), "{what}: length");
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{what}: y[{i}]");
            }
        }
        (QueryOutcome::Entries(a), QueryOutcome::Entries(b)) => {
            assert_eq!(a.len(), b.len(), "{what}: length");
            for (x, y) in a.iter().zip(b) {
                assert_eq!((x.row, x.col, x.count), (y.row, y.col, y.count), "{what}");
                assert_eq!(x.value.to_bits(), y.value.to_bits(), "{what}");
            }
        }
        _ => panic!("{what}: outcome kinds differ"),
    }
}

fn query_mix(m: usize, n: usize, rng: &mut Rng) -> Vec<Query> {
    vec![
        Query::Matvec((0..n).map(|_| rng.normal()).collect()),
        Query::MatvecT((0..m).map(|_| rng.normal()).collect()),
        Query::Row(0),
        Query::Row((m - 1) as u32),
        Query::Row(rng.usize_below(m) as u32),
        Query::Col(rng.usize_below(n) as u32),
        Query::TopK(1),
        Query::TopK(7),
        Query::TopK(100_000),
    ]
}

/// Acceptance: for every Figure-1 distribution, every query kind served
/// over the wire equals the in-process `QueryServer` answer bit for bit.
#[test]
fn remote_answers_byte_identical_for_every_method() {
    let dir = tmp_dir("byteident");
    let _ = std::fs::remove_dir_all(&dir);
    let sketches = populate_store(&SketchStore::open(&dir).unwrap());
    assert_eq!(sketches.len(), 6);
    let server = start_server(&dir, 16);
    let addr = server.local_addr().to_string();

    let mut client = RemoteSketchClient::connect(&addr).unwrap();
    client.ping().unwrap();
    assert_eq!(client.list_sketches().unwrap().len(), sketches.len());

    for (key, reference) in &sketches {
        let (m, n) = reference.shape();
        let info = client.open(key).unwrap();
        assert_eq!((info.m as usize, info.n as usize), (m, n), "{}", key.method);
        assert_eq!(info.method, key.method);

        // the in-process reference goes through a real QueryServer
        let local = QueryServer::start(Arc::clone(reference), 2);
        let mut rng = Rng::new(33);
        for (qi, q) in query_mix(m, n, &mut rng).into_iter().enumerate() {
            let want = local.submit(q.clone()).wait().unwrap();
            let got = client.query(key, &q).unwrap();
            assert_bit_identical(&got, &want, &format!("{} query {qi}", key.method));
        }
        local.shutdown();

        // pipelined batch: one write burst, in-order responses
        let mut rng = Rng::new(44);
        let batch = query_mix(m, n, &mut rng);
        let answers = client.pipeline(key, &batch).unwrap();
        assert_eq!(answers.len(), batch.len());
        for (qi, (q, got)) in batch.iter().zip(answers).enumerate() {
            let want = reference.answer(q).unwrap();
            assert_bit_identical(&got.unwrap(), &want, &format!("{} pipelined {qi}", key.method));
        }
    }

    // remote error discipline: a shape-mismatched matvec is a typed
    // error, and the connection keeps serving afterwards
    let (key0, _) = &sketches[0];
    let err = client.query(key0, &Query::Matvec(vec![1.0; 3])).unwrap_err().to_string();
    assert!(err.contains("query") || err.contains("shape"), "{err}");
    client.ping().unwrap();

    let stats = server.shutdown();
    assert!(stats.frames > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance: ≥ 8 concurrent remote clients all observe byte-identical
/// answers.
#[test]
fn eight_concurrent_clients_match_direct_answers() {
    let dir = tmp_dir("concurrent");
    let _ = std::fs::remove_dir_all(&dir);
    let sketches = populate_store(&SketchStore::open(&dir).unwrap());
    let (key, reference) = sketches
        .iter()
        .find(|(k, _)| k.method == "Bernstein")
        .expect("Bernstein sketch present")
        .clone();
    let server = start_server(&dir, 32);
    let addr = server.local_addr().to_string();

    let mut workers = Vec::new();
    for c in 0..8u64 {
        let addr = addr.clone();
        let key = key.clone();
        let reference = Arc::clone(&reference);
        workers.push(std::thread::spawn(move || {
            let mut client = RemoteSketchClient::connect(&addr).unwrap();
            let (m, n) = reference.shape();
            let mut rng = Rng::new(1000 + c);
            for (qi, q) in query_mix(m, n, &mut rng).into_iter().enumerate() {
                let want = reference.answer(&q).unwrap();
                let got = client.query(&key, &q).unwrap();
                assert_bit_identical(&got, &want, &format!("client {c} query {qi}"));
            }
        }));
    }
    for w in workers {
        w.join().expect("concurrent client panicked");
    }
    let stats = server.shutdown();
    assert!(stats.connections >= 8);
    let _ = std::fs::remove_dir_all(&dir);
}

fn raw_header(magic: [u8; 4], version: u16, opcode: u8, request_id: u64, len: u32) -> Vec<u8> {
    let mut h = Vec::with_capacity(FRAME_HEADER_LEN);
    h.extend_from_slice(&magic);
    h.extend_from_slice(&version.to_be_bytes());
    h.push(opcode);
    h.push(0);
    h.extend_from_slice(&request_id.to_be_bytes());
    h.extend_from_slice(&len.to_be_bytes());
    h
}

/// Read one response frame off a raw socket.
fn read_raw_response(stream: &mut TcpStream) -> Option<(u64, Response)> {
    let header = wire::read_frame_header(stream).ok()??;
    let h = wire::parse_frame_header(&header).ok()?;
    let payload = wire::read_payload(stream, h.len).ok()?;
    Some((h.request_id, wire::decode_response(h.opcode, &payload).ok()?))
}

fn expect_error_code(stream: &mut TcpStream, want: ErrCode, what: &str) {
    match read_raw_response(stream) {
        Some((_, Response::Error { code, .. })) => assert_eq!(code, want, "{what}"),
        other => panic!("{what}: expected typed error, got {other:?}"),
    }
}

/// Acceptance: the malformed-frame corpus — truncated length, bad magic,
/// wrong version, giant declared length, mid-payload disconnect — never
/// kills the server; it answers subsequent requests normally.
#[test]
fn malformed_frame_corpus_never_kills_the_server() {
    let dir = tmp_dir("malformed");
    let _ = std::fs::remove_dir_all(&dir);
    let sketches = populate_store(&SketchStore::open(&dir).unwrap());
    let (key, reference) = &sketches[0];
    let server = start_server(&dir, 16);
    let addr = server.local_addr();

    let assert_alive = |what: &str| {
        let mut client = RemoteSketchClient::connect(&addr.to_string()).unwrap();
        client.ping().unwrap_or_else(|e| panic!("after {what}: ping failed: {e}"));
        let got = client.query(key, &Query::TopK(3)).unwrap();
        assert_bit_identical(
            &got,
            &reference.answer(&Query::TopK(3)).unwrap(),
            &format!("after {what}"),
        );
    };

    // 1. truncated frame header: 10 of 20 bytes, then disconnect
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let good = wire::encode_request(1, &matsketch::net::Request::Ping);
        s.write_all(&good[..10]).unwrap();
        drop(s);
    }
    assert_alive("truncated header");

    // 2. bad magic: typed malformed error, then the server closes
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(&raw_header(*b"JUNK", WIRE_VERSION, 0x01, 5, 0)).unwrap();
        expect_error_code(&mut s, ErrCode::Malformed, "bad magic");
        // connection is closed after a frame fault
        let mut rest = Vec::new();
        let _ = s.read_to_end(&mut rest);
        assert!(rest.is_empty(), "no stray bytes after the error frame");
    }
    assert_alive("bad magic");

    // 3. wrong protocol version
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(&raw_header(WIRE_MAGIC, WIRE_VERSION + 7, 0x01, 6, 0)).unwrap();
        expect_error_code(&mut s, ErrCode::BadVersion, "wrong version");
    }
    assert_alive("wrong version");

    // 4. giant declared payload length
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(&raw_header(WIRE_MAGIC, WIRE_VERSION, 0x01, 7, u32::MAX)).unwrap();
        expect_error_code(&mut s, ErrCode::Oversized, "giant length");
    }
    assert_alive("giant length");

    // 5. mid-payload disconnect: a valid matvec frame cut short
    {
        let mut s = TcpStream::connect(addr).unwrap();
        let frame = wire::encode_request(
            8,
            &matsketch::net::Request::Query {
                handle: 0,
                query: Query::Matvec(vec![1.0; 64]),
            },
        );
        s.write_all(&frame[..FRAME_HEADER_LEN + 11]).unwrap();
        drop(s);
    }
    assert_alive("mid-payload disconnect");

    // 6. unknown opcode: typed error, and the SAME connection keeps
    // working afterwards (payload faults do not cost the connection)
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(&raw_header(WIRE_MAGIC, WIRE_VERSION, 0x6F, 9, 0)).unwrap();
        expect_error_code(&mut s, ErrCode::UnknownOpcode, "unknown opcode");
        let ping = wire::encode_request(10, &matsketch::net::Request::Ping);
        s.write_all(&ping).unwrap();
        match read_raw_response(&mut s) {
            Some((10, Response::Pong)) => {}
            other => panic!("same-connection ping after payload fault: {other:?}"),
        }
    }
    assert_alive("unknown opcode");

    let stats = server.shutdown();
    assert!(stats.faults >= 5, "typed faults recorded: {}", stats.faults);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The wire Shutdown sentinel winds the whole server down gracefully.
#[test]
fn shutdown_sentinel_stops_the_server() {
    let dir = tmp_dir("sentinel");
    let _ = std::fs::remove_dir_all(&dir);
    populate_store(&SketchStore::open(&dir).unwrap());
    let server = start_server(&dir, 16);
    let addr = server.local_addr();

    let mut client = RemoteSketchClient::connect(&addr.to_string()).unwrap();
    client.ping().unwrap();
    client.shutdown_server().unwrap();

    // wait() returns because the sentinel triggered teardown
    let stats = server.wait();
    assert!(stats.frames >= 2);

    // the port no longer accepts wire traffic
    let refused = match TcpStream::connect_timeout(&addr, Duration::from_millis(500)) {
        Err(_) => true,
        Ok(mut s) => {
            // a racing accept may still succeed; the server must not
            // answer a ping on it
            let ping = wire::encode_request(1, &matsketch::net::Request::Ping);
            let _ = s.write_all(&ping);
            !matches!(read_raw_response(&mut s), Some((_, Response::Pong)))
        }
    };
    assert!(refused, "server still answering after shutdown");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Handles are connection-scoped: a fresh connection cannot query with a
/// stale handle, and the error is typed.
#[test]
fn unopened_handle_is_a_typed_error() {
    let dir = tmp_dir("badhandle");
    let _ = std::fs::remove_dir_all(&dir);
    populate_store(&SketchStore::open(&dir).unwrap());
    let server = start_server(&dir, 16);
    let addr = server.local_addr();

    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let frame = wire::encode_request(
        3,
        &matsketch::net::Request::Query { handle: 42, query: Query::TopK(1) },
    );
    s.write_all(&frame).unwrap();
    expect_error_code(&mut s, ErrCode::BadHandle, "unopened handle");
    // and an open for an absent sketch is a typed store error
    let missing = StoreKey::new("no-such-dataset", "Bernstein", 1, 0);
    let frame = wire::encode_request(4, &matsketch::net::Request::OpenSketch(missing));
    s.write_all(&frame).unwrap();
    expect_error_code(&mut s, ErrCode::Store, "absent sketch");
    drop(s);

    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
