//! Integration: the serving subsystem — store round-trips are
//! bit-identical (with corruption/truncation rejected), and the
//! compressed-path query engine agrees exactly with the decode-then-
//! reference fallback for sketches produced by every `SketchMode`.
//! (The reference accumulations are computed inline here: the crate's
//! `decoded_*` twins are internal execution plans, not public API.)

use std::path::PathBuf;
use std::sync::Arc;

use matsketch::api::{QueryRequest, QueryResponse};
use matsketch::distributions::{DistributionKind, MatrixStats};
use matsketch::engine::{sketch_entry_stream, PipelineConfig, SketchMode};
use matsketch::serve::{self, QueryServer, ServableSketch, SketchStore, StoreKey};
use matsketch::sketch::{decode_sketch, encode_sketch, EncodedSketch, Sketch, SketchPlan};
use matsketch::sparse::Coo;
use matsketch::stream::ShuffledStream;
use matsketch::util::rng::Rng;

fn fixed_matrix() -> Coo {
    let mut rng = Rng::new(0x5EAF);
    let mut coo = Coo::new(20, 140);
    for i in 0..20u32 {
        for _ in 0..14 {
            coo.push(i, rng.usize_below(140) as u32, (rng.normal() as f32) + 2.0);
        }
    }
    coo.normalize();
    coo
}

fn sketch_with(mode: SketchMode, kind: DistributionKind, s: u64) -> matsketch::sketch::Sketch {
    let a = fixed_matrix();
    let stats = MatrixStats::from_coo(&a);
    let plan = SketchPlan::new(kind, s).with_seed(21);
    let (sk, _) = sketch_entry_stream(
        mode,
        ShuffledStream::new(&a, 9),
        &stats,
        &plan,
        &PipelineConfig::default(),
    )
    .unwrap();
    sk
}

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("matsketch_itest_{tag}_{}", std::process::id()))
}

/// Reference `B·x` over a decoded sketch: same f64 accumulation order as
/// the compressed path (row-major entries).
fn reference_matvec(sk: &Sketch, x: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0f64; sk.m];
    for e in &sk.entries {
        y[e.row as usize] += e.value * x[e.col as usize];
    }
    y
}

/// Reference `Bᵀ·x` over a decoded sketch.
fn reference_matvec_t(sk: &Sketch, x: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0f64; sk.n];
    for e in &sk.entries {
        y[e.col as usize] += e.value * x[e.row as usize];
    }
    y
}

/// Reference top-k over a decoded sketch: full sort under `rank_cmp`.
fn reference_top_k(sk: &Sketch, k: usize) -> Vec<matsketch::sketch::SketchEntry> {
    let mut all = sk.entries.clone();
    all.sort_by(serve::rank_cmp);
    all.truncate(k);
    all
}

#[test]
fn store_roundtrip_is_bit_identical() {
    let dir = tmp_dir("roundtrip");
    let _ = std::fs::remove_dir_all(&dir);
    let store = SketchStore::open(&dir).unwrap();
    // both payload forms: compact (Bernstein row scales) and generic (L2)
    for kind in [DistributionKind::Bernstein, DistributionKind::L2] {
        let sk = sketch_with(SketchMode::Offline, kind, 700);
        let enc = encode_sketch(&sk).unwrap();
        let key = StoreKey::new("fixed", &sk.method, 700, 21);
        store.put(&key, &enc).unwrap();
        let back = store.get(&key).unwrap().unwrap();

        // encode -> write -> read is bit-identical
        assert_eq!(back.enc.bytes, enc.bytes, "{}", sk.method);
        assert_eq!(
            (back.enc.m, back.enc.n, back.enc.s, back.enc.compact),
            (enc.m, enc.n, enc.s, enc.compact)
        );
        assert_eq!(back.enc.header_bits, enc.header_bits);
        assert_eq!(back.enc.body_bits, enc.body_bits);
        assert_eq!(back.method, sk.method);

        // ... and decode_sketch over the read-back payload reproduces the
        // decoded original exactly (same bytes, same decoder)
        let d1 = decode_sketch(&enc, &sk.method).unwrap();
        let d2 = decode_sketch(&back.enc, &back.method).unwrap();
        assert_eq!(d1.entries, d2.entries);
        assert_eq!(d1.row_scale, d2.row_scale);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn store_rejects_corrupted_checksum_and_truncated_file() {
    let dir = tmp_dir("corrupt");
    let _ = std::fs::remove_dir_all(&dir);
    let store = SketchStore::open(&dir).unwrap();
    let sk = sketch_with(SketchMode::Streaming, DistributionKind::Bernstein, 500);
    let enc = encode_sketch(&sk).unwrap();
    let key = StoreKey::new("fixed", &sk.method, 500, 21);
    let path = store.put(&key, &enc).unwrap();
    let good = std::fs::read(&path).unwrap();

    // corrupted payload byte -> checksum rejection
    let mut bad = good.clone();
    let last = bad.len() - 1;
    bad[last] ^= 0x20;
    std::fs::write(&path, &bad).unwrap();
    let err = store.get(&key).unwrap_err().to_string();
    assert!(err.contains("checksum"), "unexpected error: {err}");

    // truncated file -> rejection (never a silent partial sketch)
    std::fs::write(&path, &good[..good.len() - 7]).unwrap();
    let err = store.get(&key).unwrap_err().to_string();
    assert!(err.contains("truncated"), "unexpected error: {err}");

    // restored file reads fine again
    std::fs::write(&path, &good).unwrap();
    assert_eq!(store.get(&key).unwrap().unwrap().enc.bytes, enc.bytes);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance: compressed-path matvec / batched matvec / top-k results
/// match the decode-then-reference fallback exactly (identical f64
/// accumulation order) for sketches from every `SketchMode`, in both
/// payload forms.
#[test]
fn compressed_queries_match_decoded_fallback_in_every_mode() {
    for mode in SketchMode::all() {
        for kind in [DistributionKind::Bernstein, DistributionKind::L2] {
            let sk = sketch_with(mode, kind, 600);
            let enc = encode_sketch(&sk).unwrap();
            let dec = decode_sketch(&enc, &sk.method).unwrap();
            let what = format!("{} / {}", mode.name(), sk.method);

            let mut rng = Rng::new(33);
            let x: Vec<f64> = (0..dec.n).map(|_| rng.normal()).collect();
            let xt: Vec<f64> = (0..dec.m).map(|_| rng.normal()).collect();

            let y = serve::matvec(&enc, &x).unwrap();
            let y_ref = reference_matvec(&dec, &x);
            assert_eq!(y.len(), y_ref.len(), "{what}");
            for (i, (a, b)) in y.iter().zip(y_ref.iter()).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-12 * (1.0 + a.abs()),
                    "{what}: y[{i}] = {a} vs {b}"
                );
            }
            let yt = serve::matvec_t(&enc, &xt).unwrap();
            let yt_ref = reference_matvec_t(&dec, &xt);
            for (i, (a, b)) in yt.iter().zip(yt_ref.iter()).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-12 * (1.0 + a.abs()),
                    "{what}: yt[{i}] = {a} vs {b}"
                );
            }

            // the one-pass batched SpMM equals per-vector matvecs bitwise
            let x2: Vec<f64> = (0..dec.n).map(|_| rng.normal()).collect();
            let ys = serve::matvec_batch(&enc, &[x.clone(), x2.clone()]).unwrap();
            assert_eq!(ys[0], y, "{what}: batch[0]");
            assert_eq!(ys[1], serve::matvec(&enc, &x2).unwrap(), "{what}: batch[1]");

            for k in [1usize, 10, 100_000] {
                assert_eq!(
                    serve::top_k(&enc, k).unwrap(),
                    reference_top_k(&dec, k),
                    "{what}: top-{k}"
                );
            }

            for i in [0u32, (dec.m as u32) - 1] {
                let want: Vec<_> = dec.entries.iter().copied().filter(|e| e.row == i).collect();
                assert_eq!(serve::row_slice(&enc, i).unwrap(), want, "{what}: row {i}");
            }
        }
    }
}

#[test]
fn query_server_concurrent_answers_match_direct() {
    let sk = sketch_with(SketchMode::Sharded, DistributionKind::Bernstein, 800);
    let servable = Arc::new(ServableSketch::from_sketch(&sk).unwrap());
    let (m, n) = servable.shape();
    let server = QueryServer::start(Arc::clone(&servable), 4);

    let mut rng = Rng::new(77);
    let requests: Vec<QueryRequest> = (0..40usize)
        .map(|i| match i % 6 {
            0 => QueryRequest::Matvec((0..n).map(|_| rng.normal()).collect()),
            1 => QueryRequest::MatvecT((0..m).map(|_| rng.normal()).collect()),
            2 => QueryRequest::MatvecBatch(
                (0..2).map(|_| (0..n).map(|_| rng.normal()).collect()).collect(),
            ),
            3 => QueryRequest::Row((i % m) as u32),
            4 => QueryRequest::Col((i % n) as u32),
            _ => QueryRequest::TopK(1 + i % 9),
        })
        .collect();
    let pending = server.submit_batch(requests.clone());
    for (q, p) in requests.iter().zip(pending) {
        assert_eq!(p.wait().unwrap(), servable.answer(q).unwrap());
    }
    let stats = server.shutdown();
    assert_eq!(stats.total(), 40);
}

/// Satellite pin for the row-parallel serving path: with the split
/// threshold forced to 1, every matvec / batched-matvec / top-k answer
/// produced by a 4-worker fork/reduce must be **bit-identical** to the
/// sequential whole-payload scan, for every Figure-1 distribution.
#[test]
fn row_parallel_answers_are_bit_identical_to_sequential() {
    for kind in DistributionKind::figure1_set() {
        let sk = sketch_with(SketchMode::Offline, kind, 700);
        let servable = Arc::new(ServableSketch::from_sketch(&sk).unwrap());
        let (_, n) = servable.shape();
        let server = QueryServer::start_with(Arc::clone(&servable), 4, 1);

        let mut rng = Rng::new(0x5911);
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let xs: Vec<Vec<f64>> =
            (0..3).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();

        // matvec: element-for-element bit equality
        let QueryResponse::Vector(par) =
            server.submit(QueryRequest::Matvec(x.clone())).wait().unwrap()
        else {
            panic!("matvec answer is not a vector");
        };
        let QueryResponse::Vector(seq) =
            servable.answer(&QueryRequest::Matvec(x.clone())).unwrap()
        else {
            panic!("sequential matvec answer is not a vector");
        };
        assert_eq!(par.len(), seq.len(), "{kind:?}");
        for (i, (a, b)) in par.iter().zip(&seq).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{kind:?}: y[{i}] {a} vs {b}");
        }

        // batched matvec: every vector bit-identical
        let QueryResponse::Vectors(par_b) =
            server.submit(QueryRequest::MatvecBatch(xs.clone())).wait().unwrap()
        else {
            panic!("batch answer is not vectors");
        };
        let QueryResponse::Vectors(seq_b) =
            servable.answer(&QueryRequest::MatvecBatch(xs)).unwrap()
        else {
            panic!("sequential batch answer is not vectors");
        };
        assert_eq!(par_b.len(), seq_b.len(), "{kind:?}");
        for (pv, sv) in par_b.iter().zip(&seq_b) {
            for (a, b) in pv.iter().zip(sv) {
                assert_eq!(a.to_bits(), b.to_bits(), "{kind:?}: batch");
            }
        }

        // top-k: element-for-element equality at several k
        for k in [1usize, 5, 1_000_000] {
            assert_eq!(
                server.submit(QueryRequest::TopK(k)).wait().unwrap(),
                servable.answer(&QueryRequest::TopK(k)).unwrap(),
                "{kind:?}: top-{k}"
            );
        }
        server.shutdown();
    }
}

#[test]
fn store_get_or_build_builds_once_then_hits() {
    let dir = tmp_dir("cache");
    let _ = std::fs::remove_dir_all(&dir);
    let store = SketchStore::open(&dir).unwrap();
    let key = StoreKey::new("fixed", "Bernstein", 400, 21);

    let mut builds = 0u32;
    let (enc1, hit1) = store
        .get_or_build(&key, || {
            builds += 1;
            Ok(sketch_with(SketchMode::Offline, DistributionKind::Bernstein, 400))
        })
        .unwrap();
    assert!(!hit1);
    assert_eq!(builds, 1);

    let (enc2, hit2) = store
        .get_or_build(&key, || {
            builds += 1;
            Ok(sketch_with(SketchMode::Offline, DistributionKind::Bernstein, 400))
        })
        .unwrap();
    assert!(hit2);
    assert_eq!(builds, 1, "cache hit must not re-sketch");
    assert_eq!(enc1.bytes, enc2.bytes);

    // a served sketch from the cache answers queries
    let servable = ServableSketch::new(enc2, "Bernstein").unwrap();
    match servable.answer(&QueryRequest::TopK(5)).unwrap() {
        QueryResponse::Entries(es) => assert_eq!(es.len(), 5),
        other => panic!("unexpected outcome {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spilling_mode_sketch_serves_like_any_other() {
    // the ROADMAP-item mode: spill to disk, then serve from the encoding
    let sk = sketch_with(SketchMode::Spilling, DistributionKind::Bernstein, 500);
    assert_eq!(sk.entries.iter().map(|e| e.count as u64).sum::<u64>(), 500);
    let enc: EncodedSketch = encode_sketch(&sk).unwrap();
    assert!(enc.compact);
    let mut rng = Rng::new(1);
    let x: Vec<f64> = (0..sk.n).map(|_| rng.normal()).collect();
    let y = serve::matvec(&enc, &x).unwrap();
    let y_ref = reference_matvec(&decode_sketch(&enc, &sk.method).unwrap(), &x);
    assert_eq!(y, y_ref);
}
