//! Property-based tests of the coordinator/sampler/codec invariants
//! (mini-harness in `matsketch::testing::prop`; proptest is unavailable
//! offline — DESIGN.md §4).

use matsketch::coordinator::{sketch_stream, PipelineConfig};
use matsketch::distributions::{DistributionKind, MatrixStats};
use matsketch::samplers::{binomial, hypergeometric, multinomial_counts, ParallelReservoir};
use matsketch::sketch::{decode_sketch, encode_sketch, sketch_offline, SketchPlan};
use matsketch::sparse::{Coo, Entry};
use matsketch::stream::VecStream;
use matsketch::testing::prop::{check, shrink_u64, PropConfig};
use matsketch::util::rng::Rng;

fn random_coo(rng: &mut Rng, max_m: usize, max_n: usize) -> Coo {
    let m = 2 + rng.usize_below(max_m - 1);
    let n = 2 + rng.usize_below(max_n - 1);
    let nnz = 1 + rng.usize_below(m * n / 2 + 1);
    let mut coo = Coo::new(m, n);
    for _ in 0..nnz {
        coo.push(
            rng.usize_below(m) as u32,
            rng.usize_below(n) as u32,
            (rng.normal() as f32).abs() + 0.01,
        );
    }
    coo.normalize();
    coo
}

#[test]
fn prop_binomial_within_support() {
    check(
        PropConfig { cases: 200, seed: 10 },
        |rng| (rng.u64_below(100_000) + 1, rng.f64()),
        |_| vec![],
        |&(n, p)| {
            let mut rng = Rng::new(n ^ 0x1234);
            let x = binomial(&mut rng, n, p);
            x <= n
        },
    );
}

#[test]
fn prop_hypergeometric_within_bounds() {
    check(
        PropConfig { cases: 200, seed: 11 },
        |rng| {
            let s = rng.u64_below(10_000) + 1;
            let l = rng.u64_below(s + 1);
            let k = rng.u64_below(s + 1);
            (s, l, k)
        },
        |_| vec![],
        |&(s, l, k)| {
            let mut rng = Rng::new(s.wrapping_mul(31) ^ l);
            let t = hypergeometric(&mut rng, s, l, k);
            t <= k && t <= l && t + (s - l) >= k
        },
    );
}

#[test]
fn prop_multinomial_conserves_total() {
    check(
        PropConfig { cases: 100, seed: 12 },
        |rng| {
            let s = rng.u64_below(10_000);
            let k = 1 + rng.usize_below(20);
            let w: Vec<f64> = (0..k).map(|_| rng.f64() * 3.0).collect();
            (s, w)
        },
        |_| vec![],
        |(s, w)| {
            if w.iter().sum::<f64>() <= 0.0 {
                return true; // degenerate weights are rejected elsewhere
            }
            let mut rng = Rng::new(*s ^ 99);
            multinomial_counts(&mut rng, *s, w).iter().sum::<u64>() == *s
        },
    );
}

#[test]
fn prop_reservoir_returns_exactly_s() {
    check(
        PropConfig { cases: 60, seed: 13 },
        |rng| {
            let s = rng.u64_below(500) + 1;
            let items = 1 + rng.usize_below(2_000);
            (s, items as u64)
        },
        |&(s, items)| shrink_u64(&s).into_iter().map(|s2| (s2.max(1), items)).collect(),
        |&(s, items)| {
            let mut r = ParallelReservoir::new(s, s ^ items);
            let mut rng = Rng::new(items);
            for i in 0..items {
                r.push(i, rng.f64_open() * 5.0);
            }
            r.finalize().iter().map(|x| x.count).sum::<u64>() == s
        },
    );
}

#[test]
fn prop_offline_sketch_count_and_support() {
    // total draws == s and every sketch coordinate exists in A
    check(
        PropConfig { cases: 24, seed: 14 },
        |rng| {
            let coo = random_coo(rng, 20, 40);
            let s = rng.u64_below(2_000) + 1;
            (coo.m, coo.n, coo.entries.clone(), s)
        },
        |_| vec![],
        |(m, n, entries, s)| {
            let coo = Coo::from_entries(*m, *n, entries.clone()).unwrap();
            let a = coo.to_csr();
            let plan = SketchPlan::new(DistributionKind::Bernstein, *s).with_seed(*s);
            let sk = sketch_offline(&a, &plan).unwrap();
            let total: u64 = sk.entries.iter().map(|e| e.count as u64).sum();
            let support_ok = sk.entries.iter().all(|e| {
                entries.iter().any(|x| x.row == e.row && x.col == e.col)
            });
            total == *s && support_ok
        },
    );
}

#[test]
fn prop_pipeline_invariants() {
    // merged == s; ingested == nnz; every coordinate in support;
    // sketch is row-major sorted and duplicate-free.
    check(
        PropConfig { cases: 16, seed: 15 },
        |rng| {
            let coo = random_coo(rng, 16, 60);
            let s = rng.u64_below(800) + 1;
            let workers = 1 + rng.usize_below(4);
            (coo.m, coo.n, coo.entries.clone(), s, workers)
        },
        |_| vec![],
        |(m, n, entries, s, workers)| {
            let coo = Coo::from_entries(*m, *n, entries.clone()).unwrap();
            let stats = MatrixStats::from_coo(&coo);
            let plan = SketchPlan::new(DistributionKind::L1, *s).with_seed(*s ^ 7);
            let cfg = PipelineConfig { workers: *workers, ..Default::default() };
            let (sk, metrics) =
                sketch_stream(VecStream::new(&coo), &stats, &plan, &cfg).unwrap();
            let sorted = sk
                .entries
                .windows(2)
                .all(|w| (w[0].row, w[0].col) < (w[1].row, w[1].col));
            metrics.merged_samples == *s
                && metrics.ingested == coo.nnz() as u64
                && sorted
        },
    );
}

#[test]
fn prop_codec_roundtrip() {
    check(
        PropConfig { cases: 24, seed: 16 },
        |rng| {
            let coo = random_coo(rng, 12, 128);
            let s = rng.u64_below(3_000) + 1;
            let kind = if rng.bernoulli(0.5) {
                DistributionKind::Bernstein
            } else {
                DistributionKind::L2
            };
            (coo.m, coo.n, coo.entries.clone(), s, kind)
        },
        |_| vec![],
        |(m, n, entries, s, kind)| {
            let coo = Coo::from_entries(*m, *n, entries.clone()).unwrap();
            let a = coo.to_csr();
            let plan = SketchPlan::new(*kind, *s).with_seed(3);
            let Ok(sk) = sketch_offline(&a, &plan) else { return true };
            let enc = encode_sketch(&sk).unwrap();
            let back = decode_sketch(&enc, &sk.method).unwrap();
            back.entries.len() == sk.entries.len()
                && sk
                    .entries
                    .iter()
                    .zip(back.entries.iter())
                    .all(|(x, y)| {
                        (x.row, x.col, x.count) == (y.row, y.col, y.count)
                            && (x.value - y.value).abs()
                                <= x.value.abs() * 1e-5 + 1e-12
                    })
        },
    );
}

#[test]
fn prop_unbiasedness_coarse() {
    // For a fixed tiny matrix, the empirical mean of B over many seeds
    // approaches A in Frobenius distance.
    let coo = Coo::from_entries(
        2,
        3,
        vec![
            Entry::new(0, 0, 2.0),
            Entry::new(0, 2, -1.0),
            Entry::new(1, 1, 3.0),
        ],
    )
    .unwrap();
    let a = coo.to_csr();
    let trials = 2_000u64;
    let mut acc = vec![0.0f64; 6];
    for t in 0..trials {
        let plan = SketchPlan::new(DistributionKind::RowL1, 4).with_seed(t);
        let sk = sketch_offline(&a, &plan).unwrap();
        for e in &sk.entries {
            acc[(e.row * 3 + e.col) as usize] += e.value;
        }
    }
    let want = [2.0, 0.0, -1.0, 0.0, 3.0, 0.0];
    for i in 0..6 {
        let mean = acc[i] / trials as f64;
        assert!((mean - want[i]).abs() < 0.2, "cell {i}: {mean} vs {}", want[i]);
    }
}
