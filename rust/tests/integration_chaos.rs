//! Integration: the chaos-hardened serving stack end to end — a seeded
//! [`FaultPlan`] replays byte-identical fault schedules against a real
//! server, the retrying client answers bit-identically to a fault-free
//! run through every injected failure, reconnects restore sticky
//! generation pins atomically, the malformed-frame corpus cannot kill a
//! server that is also under fault injection, and pre-v6 peers see load
//! shedding as the legacy `busy` refusal while v6 peers get the typed
//! `overloaded` pushback with a retry-after hint.

use std::io::Write;
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use matsketch::api::{LocalClient, QueryRequest, QueryResponse, SketchClient};
use matsketch::distributions::DistributionKind;
use matsketch::engine::{self, PipelineConfig, SketchMode};
use matsketch::net::wire::{self, FRAME_HEADER_LEN, WIRE_MAGIC, WIRE_VERSION};
use matsketch::net::{
    ErrCode, FaultKind, FaultPlan, InjectedFault, NetServer, NetServerConfig, RemoteSketchClient,
    Request, Response, RetryPolicy,
};
use matsketch::serve::{coo_fingerprint, LiveConfig, LiveSketch, SketchStore, StoreKey};
use matsketch::sketch::{encode_sketch, SketchPlan};
use matsketch::sparse::{Coo, Entry};
use matsketch::util::rng::Rng;
use matsketch::Error;

const BUDGET: u64 = 600;
const SEED: u64 = 21;

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("matsketch_chaos_itest_{tag}_{}", std::process::id()))
}

fn fixed_matrix() -> Coo {
    let mut rng = Rng::new(0x7E57_4E7);
    let mut coo = Coo::new(24, 160);
    for i in 0..24u32 {
        for _ in 0..12 {
            coo.push(i, rng.usize_below(160) as u32, (rng.normal() as f32) + 1.5);
        }
    }
    coo.normalize();
    coo
}

/// The fixed entry stream the pin-regression test ingests live.
fn fixed_stream() -> (usize, usize, Vec<Entry>) {
    let coo = fixed_matrix();
    let mut entries = coo.entries.clone();
    Rng::new(99).shuffle(&mut entries);
    (coo.m, coo.n, entries)
}

/// Build + persist one Bernstein sketch, returning its key.
fn populate_store(store: &SketchStore) -> StoreKey {
    let coo = fixed_matrix();
    let fp = coo_fingerprint(&coo);
    let plan = SketchPlan::new(DistributionKind::Bernstein, BUDGET).with_seed(SEED);
    let (sk, _) = engine::sketch_coo(
        SketchMode::Offline,
        &coo,
        &plan,
        &PipelineConfig::default(),
    )
    .unwrap();
    let enc = encode_sketch(&sk).unwrap();
    let key = StoreKey::new("fixed", &sk.method, BUDGET, SEED).with_fingerprint(fp);
    store.put(&key, &enc).unwrap();
    key
}

/// Build + persist a deliberately heavy sketch: enough samples that one
/// matvec-batch holds the execution slot for milliseconds, widening the
/// saturation window the shedding probes race against.
fn populate_heavy_store(store: &SketchStore) -> StoreKey {
    let mut rng = Rng::new(0xBEEF);
    let mut coo = Coo::new(64, 2000);
    for i in 0..64u32 {
        for _ in 0..600 {
            coo.push(i, rng.usize_below(2000) as u32, (rng.normal() as f32) + 1.5);
        }
    }
    coo.normalize();
    let fp = coo_fingerprint(&coo);
    let plan = SketchPlan::new(DistributionKind::Bernstein, 24_000).with_seed(7);
    let (sk, _) = engine::sketch_coo(
        SketchMode::Offline,
        &coo,
        &plan,
        &PipelineConfig::default(),
    )
    .unwrap();
    let enc = encode_sketch(&sk).unwrap();
    let key = StoreKey::new("heavy", &sk.method, 24_000, 7).with_fingerprint(fp);
    store.put(&key, &enc).unwrap();
    key
}

/// A retry policy tuned for tests: more attempts than any scripted fault
/// chain needs, millisecond backoffs so the suite stays fast.
fn fast_retry() -> RetryPolicy {
    RetryPolicy {
        max_attempts: 10,
        base_backoff: Duration::from_millis(1),
        max_backoff: Duration::from_millis(8),
        budget: 100,
        ..RetryPolicy::default()
    }
}

fn chaos_server(store_dir: &Path, chaos: Option<Arc<FaultPlan>>, shed: usize) -> NetServer {
    NetServer::bind(
        SketchStore::open(store_dir).unwrap(),
        "127.0.0.1:0",
        NetServerConfig {
            workers_per_sketch: 2,
            max_connections: 32,
            read_timeout: Some(Duration::from_secs(10)),
            write_timeout: Some(Duration::from_secs(10)),
            shed_high_water: shed,
            chaos,
            ..Default::default()
        },
    )
    .unwrap()
}

fn raw_header(magic: [u8; 4], version: u16, opcode: u8, request_id: u64, len: u32) -> Vec<u8> {
    let mut h = Vec::with_capacity(FRAME_HEADER_LEN);
    h.extend_from_slice(&magic);
    h.extend_from_slice(&version.to_be_bytes());
    h.push(opcode);
    h.push(0);
    h.extend_from_slice(&request_id.to_be_bytes());
    h.extend_from_slice(&len.to_be_bytes());
    h
}

/// Read one response frame off a raw socket.
fn read_raw_response(stream: &mut TcpStream) -> Option<(u64, Response)> {
    let header = wire::read_frame_header(stream).ok()??;
    let h = wire::parse_frame_header(&header).ok()?;
    let payload = wire::read_payload(stream, h.len).ok()?;
    Some((h.request_id, wire::decode_response(h.version, h.opcode, &payload).ok()?))
}

/// Open `key` on a raw connection, returning the wire handle.
fn raw_open(s: &mut TcpStream, key: &StoreKey) -> u32 {
    let open = wire::encode_request(1, &Request::OpenSketch(key.clone()));
    s.write_all(&open).unwrap();
    match read_raw_response(s) {
        Some((_, Response::SketchOpened { handle, .. })) => handle,
        other => panic!("raw open: {other:?}"),
    }
}

/// Two answers must agree on the exact IEEE-754 bit patterns.
fn assert_bits_eq(a: &QueryResponse, b: &QueryResponse, what: &str) {
    match (a, b) {
        (QueryResponse::Vector(x), QueryResponse::Vector(y)) => {
            assert_eq!(x.len(), y.len(), "{what}: vector length");
            for (u, v) in x.iter().zip(y) {
                assert_eq!(u.to_bits(), v.to_bits(), "{what}");
            }
        }
        (QueryResponse::Vectors(xs), QueryResponse::Vectors(ys)) => {
            assert_eq!(xs.len(), ys.len(), "{what}: batch size");
            for (x, y) in xs.iter().zip(ys) {
                assert_eq!(x.len(), y.len(), "{what}: vector length");
                for (u, v) in x.iter().zip(y) {
                    assert_eq!(u.to_bits(), v.to_bits(), "{what}");
                }
            }
        }
        (QueryResponse::Entries(x), QueryResponse::Entries(y)) => {
            assert_eq!(x, y, "{what}");
        }
        other => panic!("{what}: mismatched response kinds {other:?}"),
    }
}

/// The chaos SPEC for the replay test: three scripted faults pinned to
/// the exact coordinates the retry loop visits (the first query dropped,
/// its retry cut short mid-write, the next retry's response corrupted),
/// plus a probabilistic tarpit — which delays but never fails, so the
/// schedule cannot exhaust the retry policy no matter what the seeded
/// draws decide.
const REPLAY_SPEC: &str = "seed=11,tarpit=0.25:1,at=0:1:disconnect,at=1:1:partial,at=2:1:corrupt";

/// The fixed query sequence both replay runs issue, covering every
/// query kind. The first entry is the one the scripted faults hit.
fn replay_queries() -> Vec<QueryRequest> {
    let x: Vec<f64> = (0..160).map(|i| (i as f64) * 0.01 - 0.8).collect();
    let xt: Vec<f64> = (0..24).map(|i| (i as f64) * 0.05 - 0.6).collect();
    vec![
        QueryRequest::Matvec(x.clone()),
        QueryRequest::MatvecT(xt),
        QueryRequest::Row(3),
        QueryRequest::Col(100),
        QueryRequest::TopK(5),
        QueryRequest::MatvecBatch(vec![x.clone(), x.iter().map(|v| -v).collect()]),
        QueryRequest::Matvec(x),
        QueryRequest::TopK(9),
    ]
}

/// One full run of the schedule: a fresh server, a fresh plan parsed
/// from the same spec, one deterministic client issuing the fixed query
/// sequence. Returns the sorted injected-fault log and the answers.
fn run_schedule(store_dir: &Path, key: &StoreKey) -> (Vec<InjectedFault>, Vec<QueryResponse>) {
    let (plan, store_fault) = FaultPlan::parse(REPLAY_SPEC).unwrap();
    assert!(store_fault.is_none());
    let plan = Arc::new(plan);
    let server = chaos_server(store_dir, Some(Arc::clone(&plan)), 0);
    let addr = server.local_addr().to_string();
    let mut client = RemoteSketchClient::connect(&addr).unwrap();
    client.set_retry_policy(fast_retry());
    let answers: Vec<QueryResponse> =
        replay_queries().iter().map(|q| client.query(key, q).unwrap()).collect();
    client.disconnect();
    server.shutdown();
    (plan.injected(), answers)
}

/// Acceptance: a fixed chaos seed replays a byte-identical fault
/// schedule (two runs, equal sorted injection logs), and every
/// idempotent query still answers — bit-identical across the two chaos
/// runs and to the fault-free local backend over the same store.
#[test]
fn same_seed_replays_the_same_faults_and_answers_stay_bit_identical() {
    let dir = tmp_dir("replay");
    let _ = std::fs::remove_dir_all(&dir);
    let key = populate_store(&SketchStore::open(&dir).unwrap());

    let (log_a, ans_a) = run_schedule(&dir, &key);
    let (log_b, ans_b) = run_schedule(&dir, &key);

    assert_eq!(log_a, log_b, "the fault schedule must replay identically");
    for (conn, frame, kind) in [
        (0, 1, FaultKind::Disconnect),
        (1, 1, FaultKind::Partial),
        (2, 1, FaultKind::Corrupt),
    ] {
        assert!(
            log_a.contains(&InjectedFault { conn, frame, kind }),
            "scripted {kind:?} at {conn}:{frame} missing from {log_a:?}"
        );
    }

    let mut local = LocalClient::open_dir(&dir).unwrap().with_workers(2);
    for ((q, a), b) in replay_queries().iter().zip(&ans_a).zip(&ans_b) {
        assert_bits_eq(a, b, "answers across two chaos runs");
        let clean = local.query(&key, q).unwrap();
        assert_bits_eq(a, &clean, "chaos'd remote vs fault-free local");
    }
    local.close().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite regression: an injected disconnect exactly between losing
/// the connection and finishing the re-open must not unpin a sticky
/// generation. The scripted plan drops the first query (conn 0, frame
/// 1) and then the redial's re-open frame itself (conn 1, frame 0); the
/// third connection finally answers — still at the pinned generation,
/// not at latest.
#[test]
fn reconnect_restores_the_sticky_pin_through_scripted_disconnects() {
    let dir = tmp_dir("pin");
    let _ = std::fs::remove_dir_all(&dir);
    let (m, n, entries) = fixed_stream();

    let plan = Arc::new(
        FaultPlan::new(0).at(0, 1, FaultKind::Disconnect).at(1, 0, FaultKind::Disconnect),
    );
    let server = chaos_server(&dir, Some(Arc::clone(&plan)), 0);
    let addr = server.local_addr().to_string();

    let sketch_plan = SketchPlan::new(DistributionKind::Bernstein, BUDGET).with_seed(SEED);
    let live_cfg = LiveConfig { epoch_entries: 0, retain: 8, workers: 2 };
    let mut live = LiveSketch::start(m, n, &sketch_plan, &live_cfg).unwrap();
    let reader = live.reader();
    let method = reader.plan().kind.name();
    let key = StoreKey::new("live-chaos", &method, BUDGET, SEED);
    server.attach_live(&key, live.reader());

    // publish three generations so "pinned at 1" and "latest" disagree
    let epoch = entries.len().div_ceil(3);
    let mut gen = 0u64;
    for chunk in entries.chunks(epoch) {
        live.push(chunk).unwrap();
        gen = live.flush().unwrap();
    }
    assert_eq!(gen, 3, "three epochs published");

    let mut client = RemoteSketchClient::connect(&addr).unwrap(); // conn 0
    client.set_retry_policy(fast_retry());
    client.set_pin(&key, Some(1));
    let probe = QueryRequest::Matvec((0..n).map(|i| (i as f64) * 0.01 - 0.5).collect());
    let (answer, answered_at) = client.query_at(&key, &probe, None).unwrap();
    assert_eq!(answered_at, 1, "reconnect must re-apply the pin, not drift to latest");

    // both scripted disconnects fired: the query lived through a drop
    // mid-query AND a drop mid-re-open
    assert_eq!(
        plan.injected(),
        vec![
            InjectedFault { conn: 0, frame: 1, kind: FaultKind::Disconnect },
            InjectedFault { conn: 1, frame: 0, kind: FaultKind::Disconnect },
        ]
    );

    // the answer is the pinned generation's, bit for bit
    let mut local = LocalClient::open_dir(&dir).unwrap().with_workers(2);
    local.attach_live(&key, live.reader());
    let (clean, g) = local.query_at(&key, &probe, Some(1)).unwrap();
    assert_eq!(g, 1);
    assert_bits_eq(&answer, &clean, "pinned answer vs local at generation 1");

    local.close().unwrap();
    client.disconnect();
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: the malformed-frame corpus fired at a server that is
/// *also* injecting tarpits and partial writes never kills it — a
/// retrying client keeps getting real answers after every hostile frame
/// — and once everything hangs up, the connection gauge returns to its
/// pre-test level (no leaked handler threads).
#[test]
fn malformed_corpus_under_standing_chaos_keeps_the_server_alive() {
    let dir = tmp_dir("corpus");
    let _ = std::fs::remove_dir_all(&dir);
    let key = populate_store(&SketchStore::open(&dir).unwrap());

    let before = matsketch::obs::global().snapshot().gauge("net_connections");

    let (plan, _) = FaultPlan::parse("seed=5,tarpit=0.3:2,partial=0.1").unwrap();
    let server = chaos_server(&dir, Some(Arc::new(plan)), 0);
    let addr = server.local_addr();

    let assert_alive = |what: &str| {
        let mut c = RemoteSketchClient::connect(&addr.to_string()).unwrap();
        c.set_retry_policy(fast_retry());
        c.ping().unwrap_or_else(|e| panic!("after {what}: ping failed: {e}"));
        match c.query(&key, &QueryRequest::TopK(3)) {
            Ok(QueryResponse::Entries(es)) => assert_eq!(es.len(), 3, "after {what}"),
            other => panic!("after {what}: top-3 answered {other:?}"),
        }
        c.disconnect();
    };

    // each hostile frame goes out raw; under standing chaos the typed
    // error reply may itself be tarpitted or cut short, so the corpus
    // only drains whatever comes back — the strong assertions are the
    // retrying client's, which must keep getting real answers
    let hostile: Vec<Vec<u8>> = vec![
        wire::encode_request(1, &Request::Ping)[..10].to_vec(), // truncated header
        raw_header(*b"JUNK", WIRE_VERSION, 0x01, 2, 0),         // bad magic
        raw_header(WIRE_MAGIC, WIRE_VERSION, 0x01, 3, u32::MAX), // giant length
        raw_header(WIRE_MAGIC, WIRE_VERSION, 0x6F, 4, 0),       // unknown opcode
        {
            // v6 top-k truncated before its trace and k words
            let mut f = raw_header(WIRE_MAGIC, WIRE_VERSION, 0x14, 5, 12);
            f.extend_from_slice(&0u32.to_be_bytes());
            f.extend_from_slice(&0u64.to_be_bytes());
            f
        },
    ];
    for (i, frame) in hostile.iter().enumerate() {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(frame).unwrap();
        if frame.len() >= FRAME_HEADER_LEN {
            let _ = read_raw_response(&mut s);
        }
        drop(s);
        assert_alive(&format!("hostile frame {i}"));
    }

    server.shutdown();

    // every handler wound down: the gauge returns to (at most) its
    // pre-test level. The obs registry is process-global and other tests
    // in this binary hold their own connections concurrently, so poll —
    // transient elevation resolves as they finish; a leak never does.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let now = matsketch::obs::global().snapshot().gauge("net_connections");
        if now <= before {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "net_connections gauge stuck at {now} (baseline {before})"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Load shedding speaks every protocol version: while hammer
/// connections keep the execution slot saturated past a high-water mark
/// of 1, a v6 probe is shed with the typed `overloaded` fault carrying
/// a nonzero retry-after hint, a v1 probe sees the same shed as the
/// legacy `busy` refusal (the v6-only code never leaks to old peers),
/// and Ping stays responsive throughout.
#[test]
fn shedding_answers_old_peers_with_busy_and_v6_with_overloaded() {
    let dir = tmp_dir("shed");
    let _ = std::fs::remove_dir_all(&dir);
    let key = populate_heavy_store(&SketchStore::open(&dir).unwrap());
    let server = chaos_server(&dir, None, 1);
    let addr = server.local_addr();

    // one shared heavy batch: 128 right-hand sides over a ~20k-sample
    // sketch hold the in-flight slot for a wide window per request
    let batch: Vec<Vec<f64>> = (0..128usize)
        .map(|r| (0..2000).map(|i| ((i + r) as f64) * 0.001 - 0.9).collect())
        .collect();

    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        for _ in 0..3 {
            let batch = &batch;
            let stop = &stop;
            let key = &key;
            scope.spawn(move || {
                let mut s = TcpStream::connect(addr).unwrap();
                s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
                let handle = raw_open(&mut s, key);
                let frame = wire::encode_request(
                    2,
                    &Request::Query {
                        handle,
                        pin: 0,
                        trace: 0,
                        query: QueryRequest::MatvecBatch(batch.clone()),
                    },
                );
                while !stop.load(Ordering::Relaxed) {
                    s.write_all(&frame).unwrap();
                    if read_raw_response(&mut s).is_none() {
                        break;
                    }
                }
            });
        }

        // v6 probe: poll until a shed lands; the fault carries the hint
        let mut v6 = TcpStream::connect(addr).unwrap();
        v6.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let v6_handle = raw_open(&mut v6, &key);
        let mut v6_hint = None;
        for id in 0..4000u64 {
            let mut f = raw_header(WIRE_MAGIC, WIRE_VERSION, 0x14, 100 + id, 28);
            f.extend_from_slice(&v6_handle.to_be_bytes());
            f.extend_from_slice(&0u64.to_be_bytes()); // pin
            f.extend_from_slice(&0u64.to_be_bytes()); // trace
            f.extend_from_slice(&1u64.to_be_bytes()); // k
            v6.write_all(&f).unwrap();
            match read_raw_response(&mut v6) {
                Some((_, Response::Error { code, retry_after_us, .. })) => {
                    assert_eq!(code, ErrCode::Overloaded, "v6 shed code");
                    v6_hint = Some(retry_after_us);
                    break;
                }
                Some(_) => {}
                None => panic!("v6 probe connection died"),
            }
        }
        let hint = v6_hint.expect("v6 probe never observed a shed in 4000 attempts");
        assert!(hint >= 500, "the retry-after hint is depth-proportional, got {hint}");

        // v1 probe: the same shed is the legacy `busy` refusal
        let mut v1 = TcpStream::connect(addr).unwrap();
        v1.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let v1_handle = raw_open(&mut v1, &key);
        let mut v1_shed = false;
        for id in 0..4000u64 {
            let mut f = raw_header(WIRE_MAGIC, 1, 0x14, 5000 + id, 12);
            f.extend_from_slice(&v1_handle.to_be_bytes());
            f.extend_from_slice(&1u64.to_be_bytes()); // k
            v1.write_all(&f).unwrap();
            match read_raw_response(&mut v1) {
                Some((_, Response::Error { code, message, retry_after_us })) => {
                    assert_eq!(code, ErrCode::Busy, "pre-v6 peers see busy: {message}");
                    assert_eq!(retry_after_us, 0, "the v6 hint never leaks into a v1 frame");
                    v1_shed = true;
                    break;
                }
                Some(_) => {}
                None => panic!("v1 probe connection died"),
            }
        }
        assert!(v1_shed, "v1 probe never observed a shed in 4000 attempts");

        // the overloaded server still answers control ops immediately
        let mut c = RemoteSketchClient::connect(&addr.to_string()).unwrap();
        c.ping().unwrap();
        c.disconnect();

        stop.store(true, Ordering::Relaxed);
    });
    let stats = server.shutdown();
    assert!(stats.faults >= 2, "both observed sheds are typed faults: {}", stats.faults);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A deadline the fault schedule cannot possibly meet surfaces as the
/// typed deadline error (not an exhausted-retries transport error), the
/// abandonment lands on the `client_deadline` counter, and clearing the
/// deadline surfaces the underlying fault class instead.
#[test]
fn impossible_deadline_is_a_typed_deadline_error() {
    let dir = tmp_dir("deadline");
    let _ = std::fs::remove_dir_all(&dir);
    let key = populate_store(&SketchStore::open(&dir).unwrap());

    // every frame of every connection is dropped before answering
    let (plan, _) = FaultPlan::parse("disconnect=1").unwrap();
    let server = chaos_server(&dir, Some(Arc::new(plan)), 0);
    let addr = server.local_addr().to_string();

    let before = matsketch::obs::global().snapshot().counter("client_deadline");
    let mut client = RemoteSketchClient::connect(&addr).unwrap();
    client.set_retry_policy(fast_retry());
    client.set_deadline(Some(Duration::from_millis(4)));
    match client.query(&key, &QueryRequest::TopK(1)) {
        Err(Error::Deadline(msg)) => {
            assert!(msg.contains("budget"), "the deadline error names the budget: {msg}")
        }
        other => panic!("expected a deadline error, got {other:?}"),
    }
    let after = matsketch::obs::global().snapshot().counter("client_deadline");
    assert!(after > before, "abandonment lands on the client_deadline counter");

    client.set_deadline(None);
    match client.query(&key, &QueryRequest::TopK(1)) {
        Err(Error::Io(_) | Error::Parse(_)) => {}
        other => panic!("expected transport exhaustion, got {other:?}"),
    }
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
