//! Integration: the experiment drivers end-to-end on reduced workloads —
//! every paper artifact's code path runs and produces sane output files.

use matsketch::datasets::{synthetic_cf, DatasetId, SyntheticConfig};
use matsketch::eval::compression::compression_dataset;
use matsketch::eval::figure1::{figure1_dataset, Figure1Config};
use matsketch::eval::tables::{characteristics, write_tables};
use matsketch::eval::theory::theory_for_profile;
use matsketch::runtime::RustEngine;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("matsketch_eval_{name}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn e1_characteristics_profiles_match_paper_regimes() {
    // The generators must land in the qualitative regimes the paper's
    // table reports: synthetic/wiki/enron moderate sr, images sr ≈ 1,
    // enron extremely sparse, images dense.
    let syn = characteristics(
        "synthetic",
        &DatasetId::Synthetic.generate_small(0).to_csr(),
        0,
    );
    assert!(syn.metrics.stable_rank > 3.0 && syn.metrics.stable_rank < 60.0);
    let img = characteristics("images", &DatasetId::Images.generate_small(0).to_csr(), 0);
    assert!(img.metrics.stable_rank < 5.0, "images sr={}", img.metrics.stable_rank);
    let enr = characteristics("enron", &DatasetId::Enron.generate_small(0).to_csr(), 0);
    let enr_density =
        enr.metrics.nnz as f64 / (enr.metrics.m as f64 * enr.metrics.n as f64);
    let img_density =
        img.metrics.nnz as f64 / (img.metrics.m as f64 * img.metrics.n as f64);
    assert!(enr_density < 0.05 && img_density > 0.5);
    // nrd/n must be well below 1 for the text matrices (the §4 key ratio)
    assert!(enr.metrics.numeric_row_density / enr.metrics.n as f64 <= 0.2);
}

#[test]
fn e1_e4_tables_written() {
    let dir = tmpdir("tables");
    let rows = vec![characteristics(
        "synthetic",
        &synthetic_cf(&SyntheticConfig { n: 500, ..Default::default() }).to_csr(),
        0,
    )];
    write_tables(&dir, &rows).unwrap();
    let t = std::fs::read_to_string(dir.join("table_characteristics.csv")).unwrap();
    assert!(t.contains("synthetic"));
    assert!(std::fs::read_to_string(dir.join("table_sample_complexity.csv"))
        .unwrap()
        .contains("synthetic"));
}

#[test]
fn e2_figure1_shape_bernstein_competitive() {
    // Paper insight 1: Bernstein is never (meaningfully) worse than any
    // other method. Check on the synthetic matrix at the largest budget.
    let a = synthetic_cf(&SyntheticConfig { n: 1_500, ..Default::default() }).to_csr();
    let cfg = Figure1Config {
        k: 10,
        svd_iters: 7,
        budget_points: 3,
        budget_lo: 0.1,
        budget_hi: 1.0,
        seed: 2,
        ..Default::default()
    };
    let pts = figure1_dataset("synthetic", &a, &cfg, &RustEngine).unwrap();
    let max_s = pts.iter().map(|p| p.s).max().unwrap();
    let at = |m: &str| {
        pts.iter()
            .find(|p| p.s == max_s && p.method == m)
            .map(|p| p.left)
            .unwrap_or(0.0)
    };
    let bern = at("Bernstein");
    for m in ["L2", "L2 trim 0.01"] {
        assert!(
            bern >= at(m) - 0.05,
            "Bernstein {bern} vs {m} {} at s={max_s}",
            at(m)
        );
    }
}

#[test]
fn e3_compression_in_paper_range() {
    let a = synthetic_cf(&SyntheticConfig { n: 2_000, ..Default::default() }).to_csr();
    let pts = compression_dataset("synthetic", &a, &[20_000, 100_000], 0).unwrap();
    for p in &pts {
        // §1: 5–22 bits/sample measured on the paper's matrices; allow a
        // wider envelope on the scaled data but require the same order.
        assert!(p.bits_per_sample < 64.0, "{p:?}");
        assert!(p.vs_raw_coo < 1.0, "{p:?}");
    }
}

#[test]
fn e6_theory_interpolation_on_real_profile() {
    let a = DatasetId::Enron.generate_small(1);
    let z = a.row_l1_norms();
    let nnz = a.nnz() as u64;
    let pts = theory_for_profile("enron", &z, a.n, &[nnz / 100, nnz * 100], 0.1, 0)
        .unwrap();
    // Bernstein never loses on eps5
    for p in &pts {
        assert!(p.eps5_bernstein <= p.eps5_l1 * (1.0 + 1e-9));
        assert!(p.eps5_bernstein <= p.eps5_rowl1 * (1.0 + 1e-9));
    }
    // interpolation direction
    assert!(pts[0].tv_from_l1 < pts[0].tv_from_rowl1);
    assert!(pts[1].tv_from_rowl1 < pts[1].tv_from_l1);
}
