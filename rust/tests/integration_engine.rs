//! Integration: the unified `Sketcher` engine — offline (alias),
//! streaming (reservoir), spilling (disk-backed reservoir), and sharded
//! (pipeline) modes all run through the one trait and produce valid
//! sketches of identical budget `s` for every Figure-1 distribution on a
//! fixed synthetic matrix.

use matsketch::distributions::{DistributionKind, MatrixStats};
use matsketch::engine::{
    build_sketcher, sketch_entry_stream, PipelineConfig, SketchMode,
};
use matsketch::sketch::SketchPlan;
use matsketch::sparse::{Coo, Entry};
use matsketch::stream::ShuffledStream;
use matsketch::util::rng::Rng;

/// Fixed synthetic matrix: 24×160, ~12 entries per row, values bounded
/// away from zero so even the trimmed-L2 baselines keep most entries.
fn fixed_matrix() -> Coo {
    let mut rng = Rng::new(0xF1F1);
    let mut coo = Coo::new(24, 160);
    for i in 0..24u32 {
        for _ in 0..12 {
            let v = (rng.normal() as f32) + 2.0;
            coo.push(i, rng.usize_below(160) as u32, v);
        }
    }
    coo.normalize();
    coo
}

#[test]
fn all_modes_produce_budget_s_for_every_figure1_distribution() {
    let a = fixed_matrix();
    let stats = MatrixStats::from_coo(&a);
    let s = 600u64;
    for kind in DistributionKind::figure1_set() {
        for mode in SketchMode::all() {
            let plan = SketchPlan::new(kind, s).with_seed(11);
            let (sk, metrics) = sketch_entry_stream(
                mode,
                ShuffledStream::new(&a, 5),
                &stats,
                &plan,
                &PipelineConfig::default(),
            )
            .unwrap_or_else(|e| panic!("{} in {} mode: {e}", kind.name(), mode.name()));

            let what = format!("{} / {}", kind.name(), mode.name());
            // identical budget across modes
            let total: u64 = sk.entries.iter().map(|e| e.count as u64).sum();
            assert_eq!(total, s, "{what}: total draws");
            assert_eq!(sk.s, s, "{what}: recorded budget");
            assert_eq!(metrics.merged_samples, s, "{what}: merged samples");
            assert_eq!(metrics.ingested, a.nnz() as u64, "{what}: ingested");
            // a valid sketch: right shape, in-bounds sorted unique
            // coordinates drawn from A's support, positive multiplicities
            assert_eq!((sk.m, sk.n), (a.m, a.n), "{what}: shape");
            assert!(
                sk.entries
                    .windows(2)
                    .all(|w| (w[0].row, w[0].col) < (w[1].row, w[1].col)),
                "{what}: not sorted/unique"
            );
            for e in &sk.entries {
                assert!((e.row as usize) < sk.m && (e.col as usize) < sk.n, "{what}");
                assert!(e.count >= 1, "{what}: zero-count entry");
                assert!(
                    a.entries.iter().any(|x| x.row == e.row && x.col == e.col),
                    "{what}: ({}, {}) outside A's support",
                    e.row,
                    e.col
                );
            }
            assert_eq!(sk.method, kind.name(), "{what}: method label");
        }
    }
}

#[test]
fn every_mode_is_unbiased_on_a_tiny_matrix() {
    let a = Coo::from_entries(
        2,
        2,
        vec![
            Entry::new(0, 0, 4.0),
            Entry::new(0, 1, -1.0),
            Entry::new(1, 1, 2.0),
        ],
    )
    .unwrap();
    let stats = MatrixStats::from_coo(&a);
    let trials = 1200u64;
    for mode in SketchMode::all() {
        let mut acc = [[0.0f64; 2]; 2];
        for t in 0..trials {
            let plan = SketchPlan::new(DistributionKind::L1, 6).with_seed(t);
            let (sk, _) = sketch_entry_stream(
                mode,
                ShuffledStream::new(&a, t),
                &stats,
                &plan,
                &PipelineConfig { workers: 2, ..Default::default() },
            )
            .unwrap();
            for e in &sk.entries {
                acc[e.row as usize][e.col as usize] += e.value;
            }
        }
        let want = [[4.0, -1.0], [0.0, 2.0]];
        for i in 0..2 {
            for j in 0..2 {
                let mean = acc[i][j] / trials as f64;
                assert!(
                    (mean - want[i][j]).abs() < 0.35,
                    "{} ({i},{j}): mean={mean} want={}",
                    mode.name(),
                    want[i][j]
                );
            }
        }
    }
}

#[test]
fn modes_agree_on_row_sampling_frequencies() {
    // All modes draw from the same distribution, so per-row sample masses
    // must agree across modes up to sampling noise.
    let a = fixed_matrix();
    let stats = MatrixStats::from_coo(&a);
    let s = 500u64;
    let trials = 30u64;
    const MODES: usize = 4;
    assert_eq!(SketchMode::all().len(), MODES);
    let mut row_mass = vec![[0.0f64; MODES]; a.m];
    for (which, mode) in SketchMode::all().into_iter().enumerate() {
        for t in 0..trials {
            let plan = SketchPlan::new(DistributionKind::Bernstein, s).with_seed(1000 + t);
            let (sk, _) = sketch_entry_stream(
                mode,
                ShuffledStream::new(&a, 7 * t + which as u64),
                &stats,
                &plan,
                &PipelineConfig::default(),
            )
            .unwrap();
            for e in &sk.entries {
                row_mass[e.row as usize][which] += e.count as f64;
            }
        }
    }
    let total = (s * trials) as f64;
    for i in 0..a.m {
        let p: Vec<f64> = (0..MODES).map(|w| row_mass[i][w] / total).collect();
        let sigma = (p[0].max(1e-4) / total).sqrt();
        for (which, &pw) in p.iter().enumerate().skip(1) {
            assert!(
                (p[0] - pw).abs() < 6.0 * sigma + 0.01,
                "row {i}: offline {:.5} vs mode#{which} {:.5}",
                p[0],
                pw
            );
        }
    }
}

#[test]
fn trait_object_lifecycle_ingest_then_finalize() {
    // Drive a Box<dyn Sketcher> by hand (the engine's contract: ingest
    // batches of any shape, then finalize).
    let a = fixed_matrix();
    let stats = MatrixStats::from_coo(&a);
    let plan = SketchPlan::new(DistributionKind::RowL1, 321).with_seed(8);
    for mode in SketchMode::all() {
        let mut sk =
            build_sketcher(mode, &stats, &plan, &PipelineConfig::default()).unwrap();
        assert_eq!(sk.mode(), mode);
        // deliberately ragged batch sizes
        let mut fed = 0usize;
        for chunk in a.entries.chunks(7) {
            sk.ingest(chunk).unwrap();
            fed += chunk.len();
        }
        assert_eq!(fed, a.nnz());
        let (sketch, metrics) = sk.finalize().unwrap();
        assert_eq!(metrics.ingested, a.nnz() as u64);
        assert_eq!(
            sketch.entries.iter().map(|e| e.count as u64).sum::<u64>(),
            321,
            "{}",
            mode.name()
        );
    }
}
