//! Integration: request-scoped tracing end to end — a split matvec
//! leaves the same span tree through the in-process backend and over
//! TCP (modulo each transport's own framing spans), the trace id
//! round-trips the wire, and disabling tracing records nothing.
//!
//! The trace collector is process-global, so every test here serializes
//! on one lock and clears the rings before recording.

use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Duration;

use matsketch::api::{LocalClient, QueryRequest, QueryResponse, RemoteClient, SketchClient};
use matsketch::distributions::DistributionKind;
use matsketch::engine::{self, PipelineConfig, SketchMode};
use matsketch::net::{NetServer, NetServerConfig};
use matsketch::obs::trace::{self, TraceRecord};
use matsketch::serve::{coo_fingerprint, SketchStore, StoreKey};
use matsketch::sketch::{encode_sketch, SketchPlan};
use matsketch::sparse::Coo;
use matsketch::util::rng::Rng;

/// One collector, many tests: serialize.
static LOCK: Mutex<()> = Mutex::new(());

const BUDGET: u64 = 600;
const SEED: u64 = 33;
const WORKERS: usize = 4;

fn fixed_matrix() -> Coo {
    let mut rng = Rng::new(0x7ACE_D00D);
    // every one of the 24 rows is occupied, so a 4-worker pool with a
    // split threshold of 1 shards a matvec into exactly 4 windows
    let mut coo = Coo::new(24, 160);
    for i in 0..24u32 {
        for _ in 0..12 {
            coo.push(i, rng.usize_below(160) as u32, (rng.normal() as f32) + 1.5);
        }
    }
    coo.normalize();
    coo
}

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("matsketch_trace_itest_{tag}_{}", std::process::id()))
}

fn populate_store(store: &SketchStore) -> StoreKey {
    let coo = fixed_matrix();
    let fp = coo_fingerprint(&coo);
    let plan = SketchPlan::new(DistributionKind::Bernstein, BUDGET).with_seed(SEED);
    let (sk, _) = engine::sketch_coo(
        SketchMode::Offline,
        &coo,
        &plan,
        &PipelineConfig::default(),
    )
    .unwrap();
    let enc = encode_sketch(&sk).unwrap();
    let key = StoreKey::new("traced", &sk.method, BUDGET, SEED).with_fingerprint(fp);
    store.put(&key, &enc).unwrap();
    key
}

fn probe() -> Vec<f64> {
    let mut rng = Rng::new(9);
    (0..160).map(|_| rng.normal()).collect()
}

/// The execution-layer child names of the root span, sorted — the part
/// of the tree both backends must agree on (framing spans like
/// `frame_decode` / `open_cache` are transport-specific).
fn exec_children(rec: &TraceRecord) -> Vec<String> {
    let root = rec.root().expect("trace has a root span");
    let mut names: Vec<String> = rec
        .children(root.id)
        .iter()
        .map(|s| s.name.clone())
        .filter(|n| matches!(n.as_str(), "queue_wait" | "split_window" | "reduce" | "exec"))
        .collect();
    names.sort();
    names
}

fn root_note<'a>(rec: &'a TraceRecord, key: &str) -> Option<&'a str> {
    rec.root()?.notes.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

/// Acceptance: a 4-worker split matvec produces one `request` root with
/// one shared queue wait, one window span per shard, and the reduction —
/// and the tree is structurally identical whether the query ran
/// in-process or over TCP (where the id also round-trips the wire).
#[test]
fn split_matvec_trace_trees_match_across_backends() {
    let _g = LOCK.lock().unwrap();
    let dir = tmp_dir("tree");
    let _ = std::fs::remove_dir_all(&dir);
    let key = populate_store(&SketchStore::open(&dir).unwrap());
    let prev_n = trace::global().one_in_n();
    trace::set_tracing_enabled(true);
    trace::set_trace_one_in_n(1);

    // local backend
    trace::global().clear();
    let mut local = LocalClient::open_dir(&dir)
        .unwrap()
        .with_workers(WORKERS)
        .with_split_min_groups(1);
    local.open(&key).unwrap();
    match local.query(&key, &QueryRequest::Matvec(probe())) {
        Ok(QueryResponse::Vector(y)) => assert_eq!(y.len(), 24),
        other => panic!("local matvec: {other:?}"),
    }
    local.close().unwrap();
    let local_rec = trace::global()
        .dump_slowest(16)
        .into_iter()
        .find(|r| r.root().is_some_and(|s| s.name == "request"))
        .expect("local query left a request trace");
    assert_eq!(root_note(&local_rec, "backend"), Some("local"));
    assert_eq!(root_note(&local_rec, "op"), Some("matvec"));
    let root_id = local_rec.root().unwrap().id;
    assert!(
        local_rec.children(root_id).iter().any(|s| s.name == "open_cache"),
        "local root records the store-open: {local_rec:?}"
    );

    // remote backend, same store and pool shape
    trace::global().clear();
    let server = NetServer::bind(
        SketchStore::open(&dir).unwrap(),
        "127.0.0.1:0",
        NetServerConfig {
            workers_per_sketch: WORKERS,
            max_connections: 8,
            read_timeout: Some(Duration::from_secs(10)),
            write_timeout: Some(Duration::from_secs(10)),
            split_min_groups: 1,
            ..Default::default()
        },
    )
    .unwrap();
    let mut remote = RemoteClient::connect(&server.local_addr().to_string()).unwrap();
    remote.open(&key).unwrap();
    match remote.query(&key, &QueryRequest::Matvec(probe())) {
        Ok(QueryResponse::Vector(y)) => assert_eq!(y.len(), 24),
        other => panic!("remote matvec: {other:?}"),
    }
    // the client-side view retired into the same process-global
    // collector; its id is the one that crossed the wire
    let client_rec = trace::global()
        .dump_slowest(16)
        .into_iter()
        .find(|r| r.root().is_some_and(|s| s.name == "client_send"))
        .expect("remote query left a client-send trace");
    let id = client_rec.trace;
    assert_ne!(id, 0);

    // fetch the server-side view of that id back over the wire (the
    // TraceDump opcode); the dump request follows the query on the same
    // connection, so the server has already retired the trace
    let remote_rec = remote
        .traces(id, 0)
        .unwrap()
        .into_iter()
        .find(|r| r.root().is_some_and(|s| s.name == "request"))
        .expect("server retained the request trace");
    remote.close().unwrap();
    assert_eq!(remote_rec.trace, id, "trace id survives the wire");
    assert_eq!(root_note(&remote_rec, "op"), Some("matvec"));
    assert!(root_note(&remote_rec, "request_id").is_some());
    let remote_root = remote_rec.root().unwrap().id;
    for framing in ["frame_decode", "reply_write"] {
        assert!(
            remote_rec.children(remote_root).iter().any(|s| s.name == framing),
            "server root records {framing}: {remote_rec:?}"
        );
    }

    // the execution trees agree: one queue wait, one window per worker,
    // one reduction — on both backends
    let mut want = vec!["queue_wait".to_string(), "reduce".to_string()];
    want.extend((0..WORKERS).map(|_| "split_window".to_string()));
    want.sort();
    assert_eq!(exec_children(&local_rec), want, "local tree: {local_rec:?}");
    assert_eq!(exec_children(&remote_rec), want, "remote tree: {remote_rec:?}");

    // every window span is annotated with its window index
    for rec in [&local_rec, &remote_rec] {
        let mut windows: Vec<&str> = rec
            .spans
            .iter()
            .filter(|s| s.name == "split_window")
            .flat_map(|s| s.notes.iter())
            .filter(|(k, _)| k == "window")
            .map(|(_, v)| v.as_str())
            .collect();
        windows.sort();
        assert_eq!(windows, ["0", "1", "2", "3"], "window notes in {rec:?}");
    }

    trace::set_trace_one_in_n(prev_n);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Disabled tracing is a true off-switch: no sampling, no records.
#[test]
fn disabled_tracing_records_nothing() {
    let _g = LOCK.lock().unwrap();
    let dir = tmp_dir("off");
    let _ = std::fs::remove_dir_all(&dir);
    let key = populate_store(&SketchStore::open(&dir).unwrap());
    let prev_n = trace::global().one_in_n();
    trace::set_trace_one_in_n(1);
    trace::set_tracing_enabled(false);
    trace::global().clear();

    let mut local = LocalClient::open_dir(&dir)
        .unwrap()
        .with_workers(WORKERS)
        .with_split_min_groups(1);
    local.open(&key).unwrap();
    match local.query(&key, &QueryRequest::Matvec(probe())) {
        Ok(QueryResponse::Vector(y)) => assert_eq!(y.len(), 24),
        other => panic!("untraced matvec: {other:?}"),
    }
    local.close().unwrap();
    assert!(
        trace::global().dump_slowest(8).is_empty(),
        "no traces retained while disabled"
    );

    trace::set_tracing_enabled(true);
    trace::set_trace_one_in_n(prev_n);
    let _ = std::fs::remove_dir_all(&dir);
}
