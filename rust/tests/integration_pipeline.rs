//! Integration: the full streaming pipeline against the offline
//! alias-table path — same distribution, statistically indistinguishable
//! sketches — plus end-to-end file-based runs (gen → stream → sketch →
//! encode → decode).

use matsketch::coordinator::{sketch_matrix, sketch_stream, PipelineConfig};
use matsketch::datasets::{enron_like, synthetic_cf, EnronConfig, SyntheticConfig};
use matsketch::distributions::{DistributionKind, MatrixStats};
use matsketch::sketch::{decode_sketch, encode_sketch, sketch_offline, SketchPlan};
use matsketch::sparse::io::{read_binary, write_binary};
use matsketch::stream::{FileStream, ShuffledStream};

#[test]
fn streaming_matches_offline_in_expectation() {
    // Both paths draw s i.i.d. samples from the same p; compare per-row
    // expected counts over repeated runs.
    let a = synthetic_cf(&SyntheticConfig { m: 40, n: 400, ..Default::default() });
    let csr = a.to_csr();
    let stats = MatrixStats::from_coo(&a);
    let s = 2_000u64;
    let trials = 25u64;
    let mut offline = vec![0f64; a.m];
    let mut streaming = vec![0f64; a.m];
    for t in 0..trials {
        let plan = SketchPlan::new(DistributionKind::Bernstein, s).with_seed(t);
        let sk1 = sketch_offline(&csr, &plan).unwrap();
        for e in &sk1.entries {
            offline[e.row as usize] += e.count as f64;
        }
        let (sk2, _) = sketch_stream(
            ShuffledStream::new(&a, 1000 + t),
            &stats,
            &plan,
            &PipelineConfig { workers: 3, ..Default::default() },
        )
        .unwrap();
        for e in &sk2.entries {
            streaming[e.row as usize] += e.count as f64;
        }
    }
    let total = (s * trials) as f64;
    for i in 0..a.m {
        let p1 = offline[i] / total;
        let p2 = streaming[i] / total;
        // row masses are ~rho_i (up to 1/40 each); allow 4-sigma-ish slack
        let sigma = (p1.max(1e-4) / total).sqrt();
        assert!(
            (p1 - p2).abs() < 6.0 * sigma + 0.004,
            "row {i}: offline {p1:.5} vs streaming {p2:.5}"
        );
    }
}

#[test]
fn file_based_end_to_end() {
    let dir = std::env::temp_dir().join("matsketch_it_pipeline");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("enron.bin");
    let a = enron_like(&EnronConfig { m: 300, n: 3_000, ..Default::default() });
    write_binary(&a, &path).unwrap();

    // read back identical
    let a2 = read_binary(&path).unwrap();
    assert_eq!(a.entries.len(), a2.entries.len());

    // pass 1: stats from the file stream
    let mut stats = MatrixStats::new(a.m, a.n);
    {
        use matsketch::stream::EntryStream;
        let mut st = FileStream::open(&path).unwrap();
        while let Some(e) = st.next_entry().unwrap() {
            stats.push(&e);
        }
    }
    assert_eq!(stats.nnz, a.nnz() as u64);

    // pass 2: streaming sketch from the file
    let plan = SketchPlan::new(DistributionKind::Bernstein, 5_000).with_seed(3);
    let stream = FileStream::open(&path).unwrap();
    let (sketch, metrics) =
        sketch_stream(stream, &stats, &plan, &PipelineConfig::default()).unwrap();
    assert_eq!(metrics.merged_samples, 5_000);
    assert_eq!(metrics.ingested, a.nnz() as u64);

    // encode → decode roundtrip
    let enc = encode_sketch(&sketch).unwrap();
    let back = decode_sketch(&enc, &sketch.method).unwrap();
    assert_eq!(back.nnz(), sketch.nnz());
    assert!(enc.bits_per_sample() < 120.0);
}

#[test]
fn convenience_sketch_matrix_works_for_all_methods() {
    let a = synthetic_cf(&SyntheticConfig { m: 30, n: 300, ..Default::default() });
    for kind in DistributionKind::figure1_set() {
        let plan = SketchPlan::new(kind, 1_000).with_seed(5);
        match sketch_matrix(&a, &plan) {
            Ok(sk) => {
                let total: u64 = sk.entries.iter().map(|e| e.count as u64).sum();
                assert_eq!(total, 1_000, "{}", kind.name());
            }
            Err(e) => panic!("{} failed: {e}", kind.name()),
        }
    }
}

#[test]
fn backpressure_with_tiny_channels_still_correct() {
    let a = synthetic_cf(&SyntheticConfig { m: 50, n: 2_000, ..Default::default() });
    let stats = MatrixStats::from_coo(&a);
    let plan = SketchPlan::new(DistributionKind::RowL1, 3_000).with_seed(9);
    let cfg = PipelineConfig { workers: 4, channel_cap: 1, batch: 16, ..Default::default() };
    let (sk, metrics) =
        sketch_stream(ShuffledStream::new(&a, 1), &stats, &plan, &cfg).unwrap();
    assert_eq!(metrics.merged_samples, 3_000);
    assert_eq!(sk.entries.iter().map(|e| e.count as u64).sum::<u64>(), 3_000);
}
