//! Integration: the unified client API. One request script — every
//! `QueryRequest` variant, including the batched matvec — is driven
//! through both backends (`LocalClient` in-process, `RemoteClient` over
//! a live loopback server) for every Figure-1 distribution, and the
//! responses must be **byte-identical**. This parameterized suite
//! replaces the hand-rolled remote-vs-local pin loops that used to live
//! in `integration_net.rs`.

use std::path::{Path, PathBuf};
use std::time::Duration;

use matsketch::api::{
    LocalClient, QueryRequest, QueryResponse, RemoteClient, SketchClient, SketchInfo,
};
use matsketch::distributions::DistributionKind;
use matsketch::engine::{self, PipelineConfig, SketchMode};
use matsketch::net::{
    run_load, run_load_with, LoadGenConfig, LoadOp, NetServer, NetServerConfig,
};
use matsketch::serve::{coo_fingerprint, LiveConfig, LiveSketch, SketchStore, StoreKey};
use matsketch::sketch::{encode_sketch, SketchPlan};
use matsketch::sparse::Coo;
use matsketch::util::rng::Rng;

const BUDGET: u64 = 600;
const SEED: u64 = 21;

fn fixed_matrix() -> Coo {
    let mut rng = Rng::new(0x7E57_4E7);
    let mut coo = Coo::new(24, 160);
    for i in 0..24u32 {
        for _ in 0..12 {
            coo.push(i, rng.usize_below(160) as u32, (rng.normal() as f32) + 1.5);
        }
    }
    coo.normalize();
    coo
}

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("matsketch_api_itest_{tag}_{}", std::process::id()))
}

/// Build + persist one sketch per Figure-1 distribution, returning the
/// keys plus each sketch's shape.
fn populate_store(store: &SketchStore) -> Vec<(StoreKey, usize, usize)> {
    let coo = fixed_matrix();
    let fp = coo_fingerprint(&coo);
    let mut out = Vec::new();
    for kind in DistributionKind::figure1_set() {
        let plan = SketchPlan::new(kind, BUDGET).with_seed(SEED);
        let (sk, _) = engine::sketch_coo(
            SketchMode::Offline,
            &coo,
            &plan,
            &PipelineConfig::default(),
        )
        .unwrap();
        let enc = encode_sketch(&sk).unwrap();
        let key = StoreKey::new("fixed", &sk.method, BUDGET, SEED).with_fingerprint(fp);
        store.put(&key, &enc).unwrap();
        out.push((key, sk.m, sk.n));
    }
    out
}

fn start_server(store_dir: &Path, max_connections: usize) -> NetServer {
    NetServer::bind(
        SketchStore::open(store_dir).unwrap(),
        "127.0.0.1:0",
        NetServerConfig {
            workers_per_sketch: 2,
            max_connections,
            read_timeout: Some(Duration::from_secs(10)),
            write_timeout: Some(Duration::from_secs(10)),
            ..Default::default()
        },
    )
    .unwrap()
}

/// The request script every backend is driven through: all `QueryRequest`
/// variants, edge indices, and a batched matvec whose first right-hand
/// side equals the single matvec probe (so batch-vs-single equivalence is
/// pinned too). Seeded, so both backends see identical requests.
fn request_script(m: usize, n: usize, seed: u64) -> Vec<QueryRequest> {
    let mut rng = Rng::new(seed);
    let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let x2: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let xt: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    vec![
        QueryRequest::Matvec(x.clone()),
        QueryRequest::MatvecT(xt),
        QueryRequest::MatvecBatch(vec![x.clone()]),
        QueryRequest::MatvecBatch(vec![x, x2.clone(), x2]),
        QueryRequest::Row(0),
        QueryRequest::Row((m - 1) as u32),
        QueryRequest::Row(rng.usize_below(m) as u32),
        QueryRequest::Col(rng.usize_below(n) as u32),
        QueryRequest::TopK(1),
        QueryRequest::TopK(7),
        QueryRequest::TopK(100_000),
    ]
}

/// Exact f64-bit equality: what "byte-identical across backends" means
/// after decoding.
fn assert_bit_identical(got: &QueryResponse, want: &QueryResponse, what: &str) {
    fn vec_eq(a: &[f64], b: &[f64], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: y[{i}]");
        }
    }
    match (got, want) {
        (QueryResponse::Vector(a), QueryResponse::Vector(b)) => vec_eq(a, b, what),
        (QueryResponse::Vectors(a), QueryResponse::Vectors(b)) => {
            assert_eq!(a.len(), b.len(), "{what}: batch size");
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                vec_eq(x, y, &format!("{what}[{i}]"));
            }
        }
        (QueryResponse::Entries(a), QueryResponse::Entries(b)) => {
            assert_eq!(a.len(), b.len(), "{what}: length");
            for (x, y) in a.iter().zip(b) {
                assert_eq!((x.row, x.col, x.count), (y.row, y.col, y.count), "{what}");
                assert_eq!(x.value.to_bits(), y.value.to_bits(), "{what}");
            }
        }
        _ => panic!("{what}: response kinds differ ({got:?} vs {want:?})"),
    }
}

/// Drive one backend through the script, once request-by-request and
/// once through the batched path, asserting the two submission paths
/// agree before returning the answers.
fn run_script(
    client: &mut dyn SketchClient,
    key: &StoreKey,
    script: &[QueryRequest],
    what: &str,
) -> Vec<QueryResponse> {
    let one_by_one: Vec<QueryResponse> = script
        .iter()
        .map(|q| client.query(key, q).unwrap())
        .collect();
    let batched = client.query_batch(key, script.to_vec()).unwrap();
    assert_eq!(batched.len(), script.len(), "{what}: batch answer count");
    for (i, (single, batch)) in one_by_one.iter().zip(batched).enumerate() {
        assert_bit_identical(&batch.unwrap(), single, &format!("{what}: batch[{i}]"));
    }
    one_by_one
}

/// Acceptance: for every Figure-1 distribution and every `QueryRequest`
/// variant (including the batched matvec over the wire), the local and
/// remote backends answer byte-identically — through both the one-shot
/// and the batched submission paths.
#[test]
fn backends_answer_identically_for_every_method_and_request() {
    let dir = tmp_dir("equiv");
    let _ = std::fs::remove_dir_all(&dir);
    let sketches = populate_store(&SketchStore::open(&dir).unwrap());
    assert_eq!(sketches.len(), 6);
    let server = start_server(&dir, 16);
    let addr = server.local_addr().to_string();

    let mut local = LocalClient::open_dir(&dir).unwrap().with_workers(2);
    let mut remote = RemoteClient::connect(&addr).unwrap();
    remote.ping().unwrap();

    for (key, m, n) in &sketches {
        let what = &key.method;
        let local_info = local.open(key).unwrap();
        let remote_info = remote.open(key).unwrap();
        assert_eq!(local_info, remote_info, "{what}: open() info");
        assert_eq!((local_info.m as usize, local_info.n as usize), (*m, *n), "{what}");

        let script = request_script(*m, *n, 33);
        let local_answers = run_script(&mut local, key, &script, &format!("{what} local"));
        let remote_answers = run_script(&mut remote, key, &script, &format!("{what} remote"));
        for (qi, (l, r)) in local_answers.iter().zip(&remote_answers).enumerate() {
            assert_bit_identical(r, l, &format!("{what} script[{qi}]"));
        }

        // the batched matvec equals its per-vector singles, end to end
        let QueryResponse::Vectors(batch) = &local_answers[3] else {
            panic!("{what}: script[3] is the k=3 batch");
        };
        let QueryRequest::MatvecBatch(xs) = &script[3] else {
            panic!("script[3] kind");
        };
        for (x, y) in xs.iter().zip(batch) {
            let single = local.query(key, &QueryRequest::Matvec(x.clone())).unwrap();
            assert_bit_identical(
                &single,
                &QueryResponse::Vector(y.clone()),
                &format!("{what} batch-vs-single"),
            );
        }
    }

    // error parity: a shape-mismatched matvec fails on both backends and
    // neither connection / pool is poisoned by it
    let (key0, _, _) = &sketches[0];
    let bad = QueryRequest::Matvec(vec![1.0; 3]);
    assert!(local.query(key0, &bad).is_err());
    assert!(remote.query(key0, &bad).is_err());
    assert!(local.query(key0, &QueryRequest::TopK(1)).is_ok());
    assert!(remote.query(key0, &QueryRequest::TopK(1)).is_ok());
    // ... including inside a batch: per-entry errors, batch not aborted
    let mixed = vec![QueryRequest::TopK(2), bad, QueryRequest::TopK(2)];
    for answers in [
        local.query_batch(key0, mixed.clone()).unwrap(),
        remote.query_batch(key0, mixed).unwrap(),
    ] {
        assert_eq!(answers.len(), 3);
        assert!(answers[0].is_ok() && answers[2].is_ok());
        assert!(answers[1].is_err());
    }

    // list() agrees (order-insensitively) across backends
    let sort = |mut v: Vec<SketchInfo>| {
        v.sort_by(|a, b| {
            (&a.dataset, &a.method, a.s, a.seed).cmp(&(&b.dataset, &b.method, b.s, b.seed))
        });
        v
    };
    assert_eq!(sort(local.list().unwrap()), sort(remote.list().unwrap()));

    local.close().unwrap();
    remote.close().unwrap();
    let stats = server.shutdown();
    assert!(stats.frames > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance: 8 concurrent client pairs (one local, one remote each)
/// all observe byte-identical answers for the same scripts.
#[test]
fn concurrent_client_pairs_stay_equivalent() {
    let dir = tmp_dir("concurrent");
    let _ = std::fs::remove_dir_all(&dir);
    let sketches = populate_store(&SketchStore::open(&dir).unwrap());
    let (key, m, n) = sketches
        .iter()
        .find(|(k, _, _)| k.method == "Bernstein")
        .expect("Bernstein sketch present")
        .clone();
    let server = start_server(&dir, 32);
    let addr = server.local_addr().to_string();

    std::thread::scope(|scope| {
        for c in 0..8u64 {
            let addr = &addr;
            let dir = &dir;
            let key = &key;
            scope.spawn(move || {
                let mut local = LocalClient::open_dir(dir).unwrap();
                let mut remote = RemoteClient::connect(addr).unwrap();
                let script = request_script(m, n, 1000 + c);
                let want = run_script(&mut local, key, &script, &format!("pair {c} local"));
                let got = run_script(&mut remote, key, &script, &format!("pair {c} remote"));
                for (qi, (l, r)) in want.iter().zip(&got).enumerate() {
                    assert_bit_identical(r, l, &format!("pair {c} script[{qi}]"));
                }
            });
        }
    });
    let stats = server.shutdown();
    assert!(stats.connections >= 8);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance (live sketches): a query pinned to generation `g` answers
/// bit-identically through the local and the remote backend, sticky pins
/// keep answering at their generation until cleared, a pin ahead of the
/// chain is the same typed error on both backends, and unpinned queries
/// under concurrent ingest always see one consistent snapshot —
/// re-asking the reported generation reproduces the answer bit for bit.
#[test]
fn pinned_generations_answer_identically_across_backends() {
    let dir = tmp_dir("livegen");
    let _ = std::fs::remove_dir_all(&dir);

    let coo = fixed_matrix();
    let (m, n) = (coo.m, coo.n);
    let plan = SketchPlan::new(DistributionKind::Bernstein, BUDGET).with_seed(SEED);
    let lcfg = LiveConfig { epoch_entries: 0, retain: 8, workers: 2 };
    let mut live = LiveSketch::start(m, n, &plan, &lcfg).unwrap();
    let key = StoreKey::new("live-fixed", "Bernstein", BUDGET, SEED);

    let server = start_server(&dir, 16);
    server.attach_live(&key, live.reader());
    let addr = server.local_addr().to_string();
    let mut local = LocalClient::open_dir(&dir).unwrap().with_workers(2);
    local.attach_live(&key, live.reader());
    let mut remote = RemoteClient::connect(&addr).unwrap();

    // three deterministic generations: thirds of the fixed stream
    let third = coo.entries.len().div_ceil(3);
    for part in coo.entries.chunks(third) {
        live.push(part).unwrap();
        live.flush().unwrap();
    }
    assert_eq!(local.generation(&key).unwrap(), 3);
    assert_eq!(remote.generation(&key).unwrap(), 3);

    let script = request_script(m, n, 77);
    for g in 1..=3u64 {
        for (qi, q) in script.iter().enumerate() {
            let (l, lg) = local.query_at(&key, q, Some(g)).unwrap();
            let (r, rg) = remote.query_at(&key, q, Some(g)).unwrap();
            assert_eq!((lg, rg), (g, g), "gen {g} script[{qi}]: answered generations");
            assert_bit_identical(&r, &l, &format!("gen {g} script[{qi}]"));
        }
    }

    // a sticky pin makes every later unpinned call answer at its
    // generation …
    remote.set_pin(&key, Some(1));
    let (pinned, g) = remote.query_at(&key, &QueryRequest::TopK(5), None).unwrap();
    assert_eq!(g, 1, "sticky pin answers at generation 1");
    let (want, _) = local.query_at(&key, &QueryRequest::TopK(5), Some(1)).unwrap();
    assert_bit_identical(&pinned, &want, "sticky pin");
    remote.set_pin(&key, None);
    // … and a pin ahead of the chain is the same typed error everywhere
    for err in [
        local.query_at(&key, &QueryRequest::TopK(1), Some(99)).unwrap_err(),
        remote.query_at(&key, &QueryRequest::TopK(1), Some(99)).unwrap_err(),
    ] {
        assert!(matches!(err, matsketch::error::Error::Generation(_)), "{err}");
    }

    // unpinned queries under concurrent ingest: whatever interleaving the
    // writer produces, every answer is computed on exactly one retained
    // snapshot, so re-asking its reported generation reproduces it
    std::thread::scope(|scope| {
        let writer = scope.spawn(move || {
            for part in coo.entries.chunks(97) {
                live.push(part).unwrap();
                live.flush().unwrap();
            }
        });
        let clients: [&mut dyn SketchClient; 2] = [&mut local, &mut remote];
        for client in clients {
            for _ in 0..20 {
                let (resp, g) =
                    client.query_at(&key, &QueryRequest::TopK(7), None).unwrap();
                assert!(g >= 3, "unpinned answers at a published generation, got {g}");
                match client.query_at(&key, &QueryRequest::TopK(7), Some(g)) {
                    Ok((again, g2)) => {
                        assert_eq!(g2, g);
                        assert_bit_identical(&again, &resp, "unpinned consistency");
                    }
                    // the generation may have retired out of the ring
                    Err(matsketch::error::Error::Generation(_)) => {}
                    Err(e) => panic!("re-pin at {g}: {e}"),
                }
            }
        }
        writer.join().unwrap();
    });

    local.close().unwrap();
    remote.close().unwrap();
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The load generator runs unmodified over either backend — the harness
/// only sees `dyn SketchClient` — and the op mix exercises the batched
/// matvec opcode under load.
#[test]
fn loadgen_drives_both_backends_through_the_trait() {
    let dir = tmp_dir("loadgen");
    let _ = std::fs::remove_dir_all(&dir);
    let sketches = populate_store(&SketchStore::open(&dir).unwrap());
    let (key, _, _) = &sketches[0];
    let cfg = LoadGenConfig {
        clients: 2,
        queries_per_client: 12,
        ops: vec![LoadOp::Matvec, LoadOp::MatvecBatch, LoadOp::Row, LoadOp::TopK],
        batch_k: 3,
        ..Default::default()
    };

    // in-process baseline: a LocalClient per load thread
    let local_report = run_load_with(
        |_| Ok(Box::new(LocalClient::open_dir(&dir)?) as Box<dyn SketchClient + Send>),
        key,
        &cfg,
    )
    .unwrap();
    assert_eq!(local_report.queries, 24);
    assert_eq!(local_report.errors, 0);
    assert!(local_report.qps > 0.0);

    // identical harness over TCP
    let server = start_server(&dir, 16);
    let addr = server.local_addr().to_string();
    let remote_report = run_load(&addr, key, &cfg).unwrap();
    assert_eq!(remote_report.queries, 24);
    assert_eq!(remote_report.errors, 0);
    assert!(remote_report.p50_us <= remote_report.p99_us);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
