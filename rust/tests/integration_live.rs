//! Integration: live generation chains (ingest-while-serving).
//!
//! The acceptance bar: a sketch served live at generation `g` is
//! **bit-identical** to the offline sketch built from the same entry
//! prefix with the same seed — for every Figure-1 distribution, checked
//! on the raw snapshot bytes and through both client backends — and
//! publication never blocks reads (queries keep answering while
//! generations land).

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use matsketch::api::{LocalClient, QueryRequest, QueryResponse, RemoteClient, SketchClient};
use matsketch::coordinator::PipelineConfig;
use matsketch::distributions::{DistributionKind, MatrixStats};
use matsketch::engine::{build_sketcher, SketchMode, Sketcher};
use matsketch::net::{NetServer, NetServerConfig};
use matsketch::serve::{LiveConfig, LiveSketch, SketchStore, StoreKey};
use matsketch::sketch::{encode_sketch, EncodedSketch, SketchPlan};
use matsketch::sparse::{Coo, Entry};
use matsketch::util::rng::Rng;

const BUDGET: u64 = 600;
const SEED: u64 = 21;

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("matsketch_live_itest_{tag}_{}", std::process::id()))
}

/// The fixed entry stream every test ingests, in arrival order.
fn fixed_stream() -> (usize, usize, Vec<Entry>) {
    let mut rng = Rng::new(0x7E57_4E7);
    let mut coo = Coo::new(24, 160);
    for i in 0..24u32 {
        for _ in 0..12 {
            coo.push(i, rng.usize_below(160) as u32, (rng.normal() as f32) + 1.5);
        }
    }
    coo.normalize();
    let mut entries = coo.entries.clone();
    Rng::new(99).shuffle(&mut entries);
    (coo.m, coo.n, entries)
}

/// The deterministic offline sketch of `prefix` — what every published
/// generation must equal, byte for byte.
fn offline_prefix(m: usize, n: usize, prefix: &[Entry], plan: &SketchPlan) -> EncodedSketch {
    let mut stats = MatrixStats::new(m, n);
    for e in prefix {
        stats.push(e);
    }
    let mut sketcher =
        build_sketcher(SketchMode::Offline, &stats, plan, &PipelineConfig::default()).unwrap();
    sketcher.ingest(prefix).unwrap();
    let (sk, _) = sketcher.finalize().unwrap();
    encode_sketch(&sk).unwrap()
}

/// Acceptance: for every `DistributionKind::figure1_set()` member, each
/// live generation's snapshot equals the offline sketch of its prefix
/// bit for bit, and a pinned query answers identically through the local
/// client, the remote client, and a from-scratch offline rebuild.
#[test]
fn live_generations_are_bit_identical_to_offline_prefix_sketches() {
    let dir = tmp_dir("bitident");
    let _ = std::fs::remove_dir_all(&dir);
    let (m, n, entries) = fixed_stream();
    let epoch = entries.len().div_ceil(4);

    let server = NetServer::bind(
        SketchStore::open(&dir).unwrap(),
        "127.0.0.1:0",
        NetServerConfig {
            workers_per_sketch: 2,
            max_connections: 16,
            read_timeout: Some(Duration::from_secs(10)),
            write_timeout: Some(Duration::from_secs(10)),
            ..Default::default()
        },
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    for kind in DistributionKind::figure1_set() {
        let plan = SketchPlan::new(kind, BUDGET).with_seed(SEED);
        let cfg = LiveConfig { epoch_entries: 0, retain: 8, workers: 2 };
        let mut live = LiveSketch::start(m, n, &plan, &cfg).unwrap();
        let reader = live.reader();
        let method = reader.plan().kind.name();
        let key = StoreKey::new("live-stream", &method, BUDGET, SEED);

        server.attach_live(&key, live.reader());
        let mut local = LocalClient::open_dir(&dir).unwrap().with_workers(2);
        local.attach_live(&key, live.reader());
        let mut remote = RemoteClient::connect(&addr).unwrap();

        let mut fed = 0usize;
        let mut gen = 0u64;
        while fed < entries.len() {
            let next = (fed + epoch).min(entries.len());
            live.push(&entries[fed..next]).unwrap();
            gen = live.flush().unwrap();
            fed = next;

            // the published snapshot IS the offline sketch of the prefix
            let want = offline_prefix(m, n, &entries[..fed], &plan);
            let snap = reader.snapshot_at(Some(gen)).unwrap();
            assert_eq!(snap.generation(), gen, "{method}: snapshot generation");
            assert_eq!(
                snap.enc.bytes, want.bytes,
                "{method} gen {gen}: live snapshot != offline prefix sketch"
            );

            // and both backends answer the pinned generation identically
            let probe = QueryRequest::Matvec((0..n).map(|i| (i as f64) * 0.01 - 0.5).collect());
            let (l, lg) = local.query_at(&key, &probe, Some(gen)).unwrap();
            let (r, rg) = remote.query_at(&key, &probe, Some(gen)).unwrap();
            assert_eq!((lg, rg), (gen, gen), "{method}: answered generations");
            match (&l, &r) {
                (QueryResponse::Vector(a), QueryResponse::Vector(b)) => {
                    assert_eq!(a.len(), b.len(), "{method}");
                    for (x, y) in a.iter().zip(b) {
                        assert_eq!(x.to_bits(), y.to_bits(), "{method} gen {gen}");
                    }
                }
                other => panic!("{method}: unexpected responses {other:?}"),
            }
        }
        assert_eq!(fed, entries.len());
        assert_eq!(gen, 4, "{method}: four epochs published");
        local.close().unwrap();
        remote.close().unwrap();
    }
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Queries never block on ingest: while a writer publishes generations
/// as fast as it can, a reader keeps getting answers the whole time, the
/// observed generation never goes backwards, and `wait_for` observes the
/// chain advancing.
#[test]
fn reads_never_block_while_generations_publish() {
    let (m, n, entries) = fixed_stream();
    let plan = SketchPlan::new(DistributionKind::Bernstein, BUDGET).with_seed(SEED);
    let cfg = LiveConfig { epoch_entries: 32, retain: 4, workers: 2 };
    let mut live = LiveSketch::start(m, n, &plan, &cfg).unwrap();
    let reader = live.reader();
    let watcher = live.reader();

    let done = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let done_ref = &done;
        let writer = scope.spawn(move || {
            for chunk in entries.chunks(32) {
                live.push(chunk).unwrap();
            }
            let g = live.flush().unwrap();
            done_ref.store(true, Ordering::Release);
            g
        });

        // watcher: wait_for sees the chain advance generation by
        // generation without ever returning a stale number
        let w = scope.spawn(move || {
            let mut seen = 0u64;
            for _ in 0..64 {
                let g = watcher.wait_for(seen + 1, Duration::from_millis(200)).unwrap();
                assert!(g >= seen, "generation went backwards: {g} < {seen}");
                if g == seen {
                    break; // timed out: chain is done advancing
                }
                seen = g;
            }
            seen
        });

        // reader: answers keep flowing during publication, each from one
        // published snapshot
        let mut answers = 0u32;
        let mut last = 0u64;
        while !done.load(Ordering::Acquire) || answers == 0 {
            let (resp, g) = reader.answer_at(None, &QueryRequest::TopK(3)).unwrap();
            assert!(g >= last, "answered generation went backwards");
            last = g;
            if g > 0 {
                assert!(matches!(resp, QueryResponse::Entries(_)));
            }
            answers += 1;
        }
        let final_gen = writer.join().unwrap();
        let watched = w.join().unwrap();
        assert!(answers > 0);
        assert!(final_gen >= 1);
        assert!(watched >= 1, "watcher saw at least one publish");
        assert!(watched <= final_gen);
        // after the writer stops, an unpinned answer lands on the final
        // generation
        let (_, g) = reader.answer_at(None, &QueryRequest::TopK(1)).unwrap();
        assert_eq!(g, final_gen);
    });
}
