//! Live recommendation serving (the paper's §1 motivation, end to end):
//! user-item ratings arrive one at a time in arbitrary order, a
//! background thread sketches them on the fly with O(1) work per rating,
//! and the *same process keeps answering queries the whole time* through
//! the live generation chain — each published generation is an immutable
//! snapshot, so readers never block on ingest.
//!
//! The demo finishes with the exactness check the design guarantees: the
//! final live generation is **bit-identical** to a one-shot offline
//! sketch of the identical stream with the same plan seed.

use std::thread;
use std::time::Duration;

use matsketch::api::{LocalClient, QueryRequest, QueryResponse, SketchClient};
use matsketch::coordinator::PipelineConfig;
use matsketch::datasets::{synthetic_cf, SyntheticConfig};
use matsketch::distributions::{DistributionKind, MatrixStats};
use matsketch::engine::{build_sketcher, SketchMode, Sketcher};
use matsketch::error::Result;
use matsketch::serve::{LiveConfig, LiveSketch, StoreKey};
use matsketch::sketch::{encode_sketch, SketchPlan};
use matsketch::sparse::Entry;
use matsketch::stream::{EntryStream, ShuffledStream};

fn main() -> Result<()> {
    // the ratings stream, in arrival order (shuffled: no row locality)
    let a = synthetic_cf(&SyntheticConfig { n: 8_000, seed: 3, ..Default::default() });
    let mut stream = ShuffledStream::new(&a, 17);
    let (m, n) = stream.shape();
    let mut arrivals: Vec<Entry> = Vec::with_capacity(a.nnz());
    while let Some(e) = stream.next_entry()? {
        arrivals.push(e);
    }
    println!("ratings stream: {m} users x {n} items, {} ratings arriving", arrivals.len());

    // live chain: a new generation publishes every `epoch_entries`
    // ratings; each snapshot is the exact offline sketch of the prefix
    let s = (arrivals.len() / 5) as u64;
    let plan = SketchPlan::new(DistributionKind::Bernstein, s).with_seed(11);
    let epoch = (arrivals.len() / 8).max(1);
    let cfg = LiveConfig { epoch_entries: epoch, retain: 4, workers: 2 };
    let mut live = LiveSketch::start(m, n, &plan, &cfg)?;
    let reader = live.reader();

    // the serving side: the ordinary client API with the chain attached
    let store_dir =
        std::env::temp_dir().join(format!("matsketch_live_demo_{}", std::process::id()));
    let mut client = LocalClient::open_dir(&store_dir)?;
    let key = StoreKey::new("ratings-live", "Bernstein", s, 11);
    client.attach_live(&key, live.reader());

    // background ingest: ratings trickle in while the foreground serves
    let feed = arrivals.clone();
    let writer = thread::spawn(move || -> Result<usize> {
        for chunk in feed.chunks(512) {
            live.push(chunk)?;
            thread::sleep(Duration::from_millis(1));
        }
        live.flush()?;
        Ok(live.ingested())
    });

    // foreground: queries observe the generation advancing mid-stream
    let mut seen = 0u64;
    let ingested = loop {
        let g = reader.wait_for(seen + 1, Duration::from_millis(100))?;
        if g > seen {
            seen = g;
            let (resp, at) = client.query_at(&key, &QueryRequest::TopK(3), None)?;
            if let QueryResponse::Entries(es) = resp {
                let best = es
                    .first()
                    .map(|e| format!("user {} x item {} ({:.3})", e.row, e.col, e.value))
                    .unwrap_or_else(|| "none yet".into());
                println!("  generation {at}: top rating {best}");
            }
        }
        if writer.is_finished() {
            break writer.join().expect("ingest thread panicked")?;
        }
    };
    let final_gen = reader.generation();
    println!("ingest complete: {ingested} ratings live at generation {final_gen}");
    assert_eq!(ingested, arrivals.len());
    assert!(final_gen >= 1, "flush must have published at least one generation");

    // exactness: the final generation equals the one-shot offline sketch
    // of the full stream, byte for byte
    let mut stats = MatrixStats::new(m, n);
    for e in &arrivals {
        stats.push(e);
    }
    let mut sketcher =
        build_sketcher(SketchMode::Offline, &stats, &plan, &PipelineConfig::default())?;
    sketcher.ingest(&arrivals)?;
    let (offline, _) = sketcher.finalize()?;
    let offline_enc = encode_sketch(&offline)?;
    let live_snap = reader.snapshot_at(Some(final_gen))?;
    assert_eq!(
        offline_enc.bytes, live_snap.enc.bytes,
        "live generation {final_gen} must be bit-identical to the offline sketch"
    );
    println!(
        "bit-identity: final live snapshot == one-shot offline sketch ({} bytes)",
        offline_enc.bytes.len()
    );

    // and the served answers agree: the pinned query runs on that very
    // snapshot, so cross-checking against the offline build is exact
    let (top, g) = client.query_at(&key, &QueryRequest::TopK(5), Some(final_gen))?;
    assert_eq!(g, final_gen);
    if let QueryResponse::Entries(es) = top {
        println!("top-5 sampled ratings at generation {g}:");
        for e in &es {
            println!(
                "  user {:>5} x item {:>4}  count={}  value={:.4}",
                e.row, e.col, e.count, e.value
            );
        }
    }
    client.close()?;
    let _ = std::fs::remove_dir_all(&store_dir);
    println!("\nServing never paused: every answer ran on an immutable snapshot.");
    Ok(())
}
