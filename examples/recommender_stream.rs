//! Streaming recommendation-engine scenario (the paper's §1 motivation):
//! user-item preferences arrive one at a time in arbitrary order; the
//! coordinator sketches them on the fly with O(1) work per rating, using
//! a-priori row-norm *estimates* (the one-pass mode of §3 — here we
//! perturb the true row norms by 2x multiplicative noise to model rough
//! prior knowledge, and also run the "all ratios equal 1" mode).

use matsketch::coordinator::PipelineConfig;
use matsketch::datasets::{synthetic_cf, SyntheticConfig};
use matsketch::distributions::{DistributionKind, MatrixStats};
use matsketch::engine::{sketch_entry_stream, SketchMode};
use matsketch::error::Result;
use matsketch::linalg::svd::{rank_k_fro, topk_svd};
use matsketch::metrics::quality::{quality_left, quality_right};
use matsketch::runtime::default_engine;
use matsketch::sketch::SketchPlan;
use matsketch::stream::ShuffledStream;

fn main() -> Result<()> {
    let a = synthetic_cf(&SyntheticConfig { n: 8_000, seed: 3, ..Default::default() });
    let a_csr = a.to_csr();
    println!("ratings matrix: {}x{} users, {} ratings", a.m, a.n, a.nnz());
    let engine = default_engine();
    println!("dense engine: {}", engine.name());

    // ground truth for quality scoring
    let k = 10;
    let svd_a = topk_svd(&a_csr, k + 4, 8, 1, engine.as_ref())?;
    let a_k = rank_k_fro(&svd_a, k);

    let exact = MatrixStats::from_coo(&a);
    let s = (a.nnz() / 5) as u64;
    let cfg = PipelineConfig::default();

    for (label, stats) in [
        ("exact row norms (2-pass)", exact.clone()),
        ("noisy row-norm estimates (1-pass, sigma=0.7)", exact.clone().with_noisy_rows(0.7, 9)),
        ("all row norms assumed equal", {
            let mut st = exact.clone();
            st.row_l1.iter_mut().for_each(|z| *z = if *z > 0.0 { 1.0 } else { 0.0 });
            st
        }),
    ] {
        let plan = SketchPlan::new(DistributionKind::Bernstein, s).with_seed(11);
        let stream = ShuffledStream::new(&a, 17);
        let (sketch, metrics) =
            sketch_entry_stream(SketchMode::Sharded, stream, &stats, &plan, &cfg)?;
        let b = sketch.to_csr();
        let svd_b = topk_svd(&b, k + 4, 8, 2, engine.as_ref())?;
        let left = quality_left(&a_csr, &svd_b, a_k, k, engine.as_ref())?;
        let right = quality_right(&a_csr, &svd_b, a_k, k)?;
        println!(
            "{label:<46} -> left={left:.3} right={right:.3}  ({:.1}M ratings/s)",
            metrics.throughput() / 1e6
        );
    }
    println!("\nRobustness to row-norm estimates is §3's claim: even rough ratios work.");
    Ok(())
}
