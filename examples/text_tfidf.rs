//! Text-corpus scenario: sketching a tf-idf term-document matrix
//! (Enron/Wikipedia-style) and comparing the paper's distribution against
//! the baselines at a fixed budget — a single-budget slice of Figure 1.

use matsketch::datasets::{enron_like, EnronConfig};
use matsketch::distributions::DistributionKind;
use matsketch::engine::{sketch_csr, PipelineConfig, SketchMode};
use matsketch::error::Result;
use matsketch::linalg::svd::{rank_k_fro, topk_svd};
use matsketch::metrics::quality::{quality_left, quality_right};
use matsketch::runtime::default_engine;
use matsketch::sketch::{encode_sketch, SketchPlan};

fn main() -> Result<()> {
    let a = enron_like(&EnronConfig { m: 1_000, n: 12_000, seed: 1, ..Default::default() })
        .to_csr();
    println!("tf-idf matrix: {} terms x {} documents, nnz={}", a.m, a.n, a.nnz());
    let engine = default_engine();

    let k = 12;
    let svd_a = topk_svd(&a, k + 4, 8, 5, engine.as_ref())?;
    let a_k = rank_k_fro(&svd_a, k);
    let s = (a.nnz() / 4) as u64;
    println!("budget s = {s} (~25% of nnz), k = {k}\n");
    println!("{:<14} {:>8} {:>8} {:>12}", "method", "left", "right", "bits/sample");

    for kind in DistributionKind::figure1_set() {
        let plan = SketchPlan::new(kind, s).with_seed(23);
        // the engine's offline (alias-table) mode — the evaluation
        // reference path behind the same Sketcher trait as streaming
        let sk = match sketch_csr(SketchMode::Offline, &a, &plan, &PipelineConfig::default())
        {
            Ok((sk, _metrics)) => sk,
            Err(e) => {
                println!("{:<14} failed: {e}", kind.name());
                continue;
            }
        };
        let enc = encode_sketch(&sk)?;
        let b = sk.to_csr();
        let svd_b = topk_svd(&b, k + 4, 8, 6, engine.as_ref())?;
        let left = quality_left(&a, &svd_b, a_k, k, engine.as_ref())?;
        let right = quality_right(&a, &svd_b, a_k, k)?;
        println!(
            "{:<14} {:>8.3} {:>8.3} {:>12.2}",
            kind.name(),
            left,
            right,
            enc.bits_per_sample()
        );
    }
    println!("\nExpected shape (paper §6.2): Bernstein >= Row-L1/L1 > trimmed L2 > raw L2.");
    Ok(())
}
