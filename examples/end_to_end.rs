//! End-to-end driver (the EXPERIMENTS.md run): exercises the entire
//! three-layer stack on a real small workload, proving the layers compose:
//!
//!  1. generate the paper's four datasets (laptop scale);
//!  2. stream each through the L3 coordinator (workers + backpressure +
//!     Appendix-A reservoirs) with the Bernstein distribution;
//!  3. evaluate sketches with the AOT XLA engine (L2 JAX graphs + L1
//!     Pallas kernels via PJRT): subspace-iteration SVD + Figure-1 quality;
//!  4. encode sketches with the compact codec and report bits/sample;
//!  5. print the paper's headline metric per dataset;
//!  6. persist one sketch into the on-disk store and drive the **same**
//!     query script through the unified `SketchClient` API twice — the
//!     in-process `LocalClient` and, over a live TCP server, the
//!     `RemoteClient` — asserting the two backends answer identically
//!     (matvec, batched matvec, top-k, row slice).
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use std::time::Instant;

use matsketch::api::{LocalClient, QueryRequest, QueryResponse, RemoteClient, SketchClient};
use matsketch::coordinator::PipelineConfig;
use matsketch::datasets::DatasetId;
use matsketch::distributions::{DistributionKind, MatrixStats};
use matsketch::engine::{sketch_entry_stream, SketchMode};
use matsketch::error::Result;
use matsketch::linalg::svd::{rank_k_fro, topk_svd};
use matsketch::metrics::quality::{quality_left, quality_right};
use matsketch::net::{NetServer, NetServerConfig};
use matsketch::runtime::default_engine;
use matsketch::serve::{coo_fingerprint, SketchStore, StoreKey};
use matsketch::sketch::SketchPlan;
use matsketch::stream::ShuffledStream;
use matsketch::util::rng::Rng;

/// The shared serving demo: one request script, any backend. Returns the
/// responses so the caller can pin local == remote.
fn serve_demo(
    client: &mut dyn SketchClient,
    key: &StoreKey,
    label: &str,
) -> Result<Vec<QueryResponse>> {
    let info = client.open(key)?;
    println!("\n{label}: serving {}x{} sketch (s={})", info.m, info.n, info.s);
    let mut rng = Rng::new(7);
    let x: Vec<f64> = (0..info.n as usize).map(|_| rng.normal()).collect();
    let script = vec![
        QueryRequest::Matvec(x.clone()),
        QueryRequest::MatvecBatch(vec![x.clone(), x.iter().map(|v| -v).collect()]),
        QueryRequest::TopK(5),
        QueryRequest::Row(0),
    ];
    let mut out = Vec::new();
    for answer in client.query_batch(key, script)? {
        let answer = answer?;
        match &answer {
            QueryResponse::Vector(y) => {
                let norm = y.iter().map(|v| v * v).sum::<f64>().sqrt();
                println!("  matvec: |y|_2 = {norm:.4e}");
            }
            QueryResponse::Vectors(ys) => {
                println!("  batched matvec: {} vectors in one payload pass", ys.len())
            }
            QueryResponse::Entries(es) => println!("  entries: {} returned", es.len()),
        }
        out.push(answer);
    }
    client.close()?;
    Ok(out)
}

fn main() -> Result<()> {
    let engine = default_engine();
    println!("matsketch end-to-end | engine = {}\n", engine.name());
    let small = std::env::args().any(|a| a == "--small");
    let k = 20;
    println!(
        "{:<11} {:>9} {:>11} {:>8} {:>8} {:>8} {:>11} {:>9}",
        "dataset", "nnz", "s", "left", "right", "bits/s", "nnz/s(M)", "secs"
    );

    for id in DatasetId::all() {
        let t0 = Instant::now();
        let coo = if small { id.generate_small(0) } else { id.generate(0) };
        let a = coo.to_csr();
        let stats = MatrixStats::from_coo(&coo); // pass 1 (streaming)

        // ground truth rank-k mass of A
        let svd_a = topk_svd(&a, k + 4, 8, 1, engine.as_ref())?;
        let a_k = rank_k_fro(&svd_a, k);

        // pass 2: the streaming pipeline at s = nnz/5
        let s = (a.nnz() as u64 / 5).max(5_000);
        let plan = SketchPlan::new(DistributionKind::Bernstein, s).with_seed(99);
        let stream = ShuffledStream::new(&coo, 5);
        let (sketch, metrics) = sketch_entry_stream(
            SketchMode::Sharded,
            stream,
            &stats,
            &plan,
            &PipelineConfig::default(),
        )?;

        // evaluate through the AOT engine
        let b = sketch.to_csr();
        let svd_b = topk_svd(&b, k + 4, 8, 2, engine.as_ref())?;
        let left = quality_left(&a, &svd_b, a_k, k, engine.as_ref())?;
        let right = quality_right(&a, &svd_b, a_k, k)?;
        let enc = matsketch::sketch::encode_sketch(&sketch)?;

        println!(
            "{:<11} {:>9} {:>11} {:>8.3} {:>8.3} {:>8.2} {:>11.2} {:>9.1}",
            id.name(),
            a.nnz(),
            s,
            left,
            right,
            enc.bits_per_sample(),
            metrics.throughput() / 1e6,
            t0.elapsed().as_secs_f64()
        );
    }
    // 6. the serving story, through the one client API: persist a
    // sketch, then run the identical query script against the local
    // backend and a live TCP server, and pin the answers equal.
    let store_dir = std::env::temp_dir().join("matsketch-e2e-store");
    let store = SketchStore::open(&store_dir)?;
    let coo = DatasetId::Synthetic.generate_small(0);
    let s = (coo.nnz() as u64 / 5).max(5_000);
    let plan = SketchPlan::new(DistributionKind::Bernstein, s).with_seed(99);
    let key = StoreKey::new("synthetic-small", &plan.kind.name(), s, plan.seed)
        .with_fingerprint(coo_fingerprint(&coo));
    let (_, cache_hit) = store.get_or_build(&key, || {
        let stats = MatrixStats::from_coo(&coo);
        let (sk, _) = sketch_entry_stream(
            SketchMode::Sharded,
            ShuffledStream::new(&coo, 5),
            &stats,
            &plan,
            &PipelineConfig::default(),
        )?;
        Ok(sk)
    })?;
    println!(
        "\nstore: {} ({}), cache {}",
        key.file_name(),
        store.dir().display(),
        if cache_hit { "hit" } else { "miss -> built + persisted" }
    );

    // local backend
    let mut local = LocalClient::new(store);
    let local_answers = serve_demo(&mut local, &key, "local client")?;

    // remote backend: same script over the wire
    let net = NetServer::bind(
        SketchStore::open(&store_dir)?,
        "127.0.0.1:0",
        NetServerConfig::default(),
    )?;
    let addr = net.local_addr().to_string();
    let mut remote = RemoteClient::connect(&addr)?;
    let remote_answers = serve_demo(&mut remote, &key, "remote client")?;

    assert_eq!(
        local_answers, remote_answers,
        "remote answers differ from in-process"
    );
    println!("  backends agree: {} answers identical over TCP", remote_answers.len());

    remote.shutdown_server()?;
    let net_stats = net.wait();
    println!("  net: {} frames over {} connections", net_stats.frames, net_stats.connections);

    println!(
        "\nAll layers composed: L3 streaming pipeline -> L2/L1 AOT artifacts via PJRT \
         -> sketch store -> one SketchClient API over local + TCP backends."
    );
    Ok(())
}
