//! End-to-end driver (the EXPERIMENTS.md run): exercises the entire
//! three-layer stack on a real small workload, proving the layers compose:
//!
//!  1. generate the paper's four datasets (laptop scale);
//!  2. stream each through the L3 coordinator (workers + backpressure +
//!     Appendix-A reservoirs) with the Bernstein distribution;
//!  3. evaluate sketches with the AOT XLA engine (L2 JAX graphs + L1
//!     Pallas kernels via PJRT): subspace-iteration SVD + Figure-1 quality;
//!  4. encode sketches with the compact codec and report bits/sample;
//!  5. print the paper's headline metric per dataset.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use std::time::Instant;

use matsketch::coordinator::PipelineConfig;
use matsketch::datasets::DatasetId;
use matsketch::distributions::{DistributionKind, MatrixStats};
use matsketch::engine::{sketch_entry_stream, SketchMode};
use matsketch::error::Result;
use matsketch::linalg::svd::{rank_k_fro, topk_svd};
use matsketch::metrics::quality::{quality_left, quality_right};
use matsketch::runtime::default_engine;
use matsketch::sketch::{encode_sketch, SketchPlan};
use matsketch::stream::ShuffledStream;

fn main() -> Result<()> {
    let engine = default_engine();
    println!("matsketch end-to-end | engine = {}\n", engine.name());
    let small = std::env::args().any(|a| a == "--small");
    let k = 20;
    println!(
        "{:<11} {:>9} {:>11} {:>8} {:>8} {:>8} {:>11} {:>9}",
        "dataset", "nnz", "s", "left", "right", "bits/s", "nnz/s(M)", "secs"
    );

    for id in DatasetId::all() {
        let t0 = Instant::now();
        let coo = if small { id.generate_small(0) } else { id.generate(0) };
        let a = coo.to_csr();
        let stats = MatrixStats::from_coo(&coo); // pass 1 (streaming)

        // ground truth rank-k mass of A
        let svd_a = topk_svd(&a, k + 4, 8, 1, engine.as_ref())?;
        let a_k = rank_k_fro(&svd_a, k);

        // pass 2: the streaming pipeline at s = nnz/5
        let s = (a.nnz() as u64 / 5).max(5_000);
        let plan = SketchPlan::new(DistributionKind::Bernstein, s).with_seed(99);
        let stream = ShuffledStream::new(&coo, 5);
        let (sketch, metrics) = sketch_entry_stream(
            SketchMode::Sharded,
            stream,
            &stats,
            &plan,
            &PipelineConfig::default(),
        )?;

        // evaluate through the AOT engine
        let b = sketch.to_csr();
        let svd_b = topk_svd(&b, k + 4, 8, 2, engine.as_ref())?;
        let left = quality_left(&a, &svd_b, a_k, k, engine.as_ref())?;
        let right = quality_right(&a, &svd_b, a_k, k)?;
        let enc = encode_sketch(&sketch)?;

        println!(
            "{:<11} {:>9} {:>11} {:>8.3} {:>8.3} {:>8.2} {:>11.2} {:>9.1}",
            id.name(),
            a.nnz(),
            s,
            left,
            right,
            enc.bits_per_sample(),
            metrics.throughput() / 1e6,
            t0.elapsed().as_secs_f64()
        );
    }
    println!("\nAll layers composed: L3 streaming pipeline -> L2/L1 AOT artifacts via PJRT.");
    Ok(())
}
