//! Quickstart: sketch a data matrix with the paper's Bernstein
//! distribution and inspect the result.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use matsketch::prelude::*;
use matsketch::datasets::{synthetic_cf, SyntheticConfig};
use matsketch::engine::sketch_coo;
use matsketch::sketch::encode_sketch;

fn main() -> Result<()> {
    // 1. A data matrix: the paper's synthetic collaborative-filtering
    //    generator (items x users, low-rank + noise, popularity skew).
    let a = synthetic_cf(&SyntheticConfig { n: 5_000, seed: 42, ..Default::default() });
    println!("A: {}x{} with {} non-zeros", a.m, a.n, a.nnz());

    // 2. Sketch with s = 10% of nnz through the unified engine in sharded
    //    mode (stats pass + shuffled-order sampling pass). Swapping
    //    SketchMode::Offline or ::Streaming here changes only the
    //    execution strategy, never the sampling law.
    let s = (a.nnz() / 10) as u64;
    let plan = SketchPlan::new(DistributionKind::Bernstein, s).with_seed(7);
    let (sketch, metrics) =
        sketch_coo(SketchMode::Sharded, &a, &plan, &PipelineConfig::default())?;
    println!(
        "B: {} distinct coordinates from {} draws ({}x sparser than A, {:.1}M nnz/s)",
        sketch.nnz(),
        s,
        a.nnz() / sketch.nnz().max(1),
        metrics.throughput() / 1e6
    );

    // 3. The sketch is unbiased (E[B] = A). A low-variance check: for the
    //    L1 family, E[Σ|B_ij|] = ‖A‖₁ with per-draw contributions of equal
    //    magnitude, so the empirical L1 masses must agree tightly.
    let a_mass: f64 = a.entries.iter().map(|e| e.val.abs() as f64).sum();
    let b_mass: f64 = sketch.entries.iter().map(|e| e.value.abs()).sum();
    println!(
        "‖A‖₁ = {a_mass:.3e}, ‖B‖₁ = {b_mass:.3e} (rel err {:.4})",
        (a_mass - b_mass).abs() / a_mass
    );

    // 4. Compact encoding (the paper's 5-22 bits/sample claim).
    let enc = encode_sketch(&sketch)?;
    println!(
        "encoded: {} bytes = {:.2} bits/sample (COO list would need 96 bits/coordinate)",
        enc.bytes.len(),
        enc.bits_per_sample()
    );

    // 5. Spectral error vs the all-zeros sketch baseline.
    let b = sketch.to_csr();
    let err = spectral_err(&a, &b);
    let norm_a = matsketch::linalg::spectral_norm(&a.to_csr(), 60, 1);
    println!("||A - B||_2 / ||A||_2 = {:.3}", err / norm_a);
    Ok(())
}

/// ‖A − B‖₂ via power iteration on the difference (dense-free).
fn spectral_err(a: &Coo, b: &Csr) -> f64 {
    let mut diff = a.clone();
    for i in 0..b.m {
        for (j, v) in b.row(i) {
            diff.push(i as u32, j, -v);
        }
    }
    diff.normalize();
    matsketch::linalg::spectral_norm(&diff.to_csr(), 60, 2)
}
